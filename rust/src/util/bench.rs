//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, repeated timed samples, outlier-robust statistics and
//! a human-readable + CSV + JSON report. Every `benches/*.rs` target
//! (declared with `harness = false`) drives this. The JSON form
//! (`--json <path>` after `--`, or `TETRIS_BENCH_JSON=<path>`) feeds
//! the CI bench-regression gate: `scripts/bench_compare.py` diffs a
//! fresh report against the committed `BENCH_baseline.json` and fails
//! on hot-path median regressions beyond tolerance.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::percentile;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Per-iteration wall time, seconds, sorted ascending.
    pub samples_s: Vec<f64>,
    /// Optional user metric (e.g. simulated cycles) attached via
    /// [`Bencher::metric`].
    pub metrics: Vec<(String, f64)>,
}

impl Measurement {
    pub fn median_s(&self) -> f64 {
        percentile(&self.samples_s, 0.5)
    }

    pub fn p05_s(&self) -> f64 {
        percentile(&self.samples_s, 0.05)
    }

    pub fn p95_s(&self) -> f64 {
        percentile(&self.samples_s, 0.95)
    }

    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Fast enough that the full paper-figure suite completes in
        // minutes; override with TETRIS_BENCH_SECONDS (resolved via
        // `engine::env`) for longer runs.
        let secs: f64 = crate::engine::env::bench_seconds();
        Self {
            warmup: Duration::from_secs_f64(secs * 0.33),
            measure: Duration::from_secs_f64(secs),
            min_samples: 10,
            max_samples: 2_000,
        }
    }
}

/// Collects measurements and renders the report.
pub struct Harness {
    pub config: BenchConfig,
    pub title: String,
    results: Vec<Measurement>,
}

impl Harness {
    pub fn new(title: &str) -> Self {
        Self { config: BenchConfig::default(), title: title.to_string(), results: Vec::new() }
    }

    /// Time `f` repeatedly; the closure returns a value that is
    /// black-boxed to keep the optimizer honest.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // Warmup.
        let start = Instant::now();
        let mut iters_hint = 1u64;
        while start.elapsed() < self.config.warmup {
            for _ in 0..iters_hint {
                black_box(f());
            }
            iters_hint = (iters_hint * 2).min(1 << 20);
        }
        // Measure.
        let mut samples = Vec::new();
        let begin = Instant::now();
        while (begin.elapsed() < self.config.measure || samples.len() < self.config.min_samples)
            && samples.len() < self.config.max_samples
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(f64::total_cmp);
        self.results.push(Measurement {
            name: name.to_string(),
            samples_s: samples,
            metrics: Vec::new(),
        });
        self.results.last().unwrap()
    }

    /// Record an analytic (non-timed) metric row — used for simulated
    /// cycles, energy, area: quantities the paper reports that are
    /// computed, not wall-clock timed.
    pub fn metric_row(&mut self, name: &str, metrics: Vec<(String, f64)>) {
        self.results.push(Measurement { name: name.to_string(), samples_s: vec![0.0], metrics });
    }

    /// Attach a metric to the most recent measurement.
    pub fn metric(&mut self, key: &str, value: f64) {
        if let Some(last) = self.results.last_mut() {
            last.metrics.push((key.to_string(), value));
        }
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Render the human-readable report to stdout and optionally CSV.
    pub fn report(&self) {
        println!("\n== {} ==", self.title);
        let timed: Vec<_> = self.results.iter().filter(|m| m.samples_s.len() > 1).collect();
        if !timed.is_empty() {
            println!("{:<44} {:>12} {:>12} {:>12} {:>8}", "benchmark", "median", "p05", "p95", "n");
            for m in &timed {
                println!(
                    "{:<44} {:>12} {:>12} {:>12} {:>8}",
                    m.name,
                    fmt_time(m.median_s()),
                    fmt_time(m.p05_s()),
                    fmt_time(m.p95_s()),
                    m.samples_s.len()
                );
            }
        }
        let metric_rows: Vec<_> = self.results.iter().filter(|m| !m.metrics.is_empty()).collect();
        if !metric_rows.is_empty() {
            println!("-- metrics --");
            for m in metric_rows {
                let kv: Vec<String> =
                    m.metrics.iter().map(|(k, v)| format!("{k}={v:.4}")).collect();
                println!("{:<44} {}", m.name, kv.join("  "));
            }
        }
    }

    /// Machine-readable report (the `--json` bench output mode): one
    /// entry per measurement with robust stats plus attached metrics.
    /// Deterministic key order (BTreeMap-backed objects) keeps diffs
    /// and baseline comparisons stable.
    pub fn to_json(&self) -> Json {
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|m| {
                Json::obj([
                    ("name", Json::Str(m.name.clone())),
                    ("median_s", Json::Num(m.median_s())),
                    ("p05_s", Json::Num(m.p05_s())),
                    ("p95_s", Json::Num(m.p95_s())),
                    ("samples", Json::Num(m.samples_s.len() as f64)),
                    (
                        "metrics",
                        Json::Obj(
                            m.metrics
                                .iter()
                                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj([
            ("title", Json::Str(self.title.clone())),
            ("results", Json::Arr(results)),
        ])
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
    }

    /// The JSON sink this bench invocation asked for, if any:
    /// `cargo bench --bench <name> -- --json <path>` (or
    /// `--json=<path>`), else the `TETRIS_BENCH_JSON` env var.
    pub fn json_target() -> Option<std::path::PathBuf> {
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            if a == "--json" {
                if let Some(p) = args.next() {
                    return Some(p.into());
                }
            } else if let Some(p) = a.strip_prefix("--json=") {
                return Some(p.into());
            }
        }
        crate::engine::env::bench_json()
    }

    /// Render the human report and honor the `--json` output mode —
    /// the one-call tail every bench target wants.
    pub fn emit(&self) {
        self.report();
        if let Some(path) = Self::json_target() {
            match self.write_json(&path) {
                Ok(()) => eprintln!("bench JSON written to {}", path.display()),
                Err(e) => eprintln!("bench JSON write to {} failed: {e}", path.display()),
            }
        }
    }

    /// Write a CSV file of all samples + metrics.
    pub fn write_csv(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "name,median_s,p05_s,p95_s,n,metrics")?;
        for m in &self.results {
            let kv: Vec<String> = m.metrics.iter().map(|(k, v)| format!("{k}={v}")).collect();
            writeln!(
                f,
                "{},{},{},{},{},{}",
                m.name,
                m.median_s(),
                m.p05_s(),
                m.p95_s(),
                m.samples_s.len(),
                kv.join(";")
            )?;
        }
        Ok(())
    }
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut h = Harness::new("test");
        h.config.warmup = Duration::from_millis(5);
        h.config.measure = Duration::from_millis(20);
        let m = h.bench("noop-ish", || (0..100).sum::<u64>());
        assert!(m.samples_s.len() >= 10);
        assert!(m.median_s() >= 0.0);
    }

    #[test]
    fn metric_rows_and_lookup() {
        let mut h = Harness::new("t");
        h.metric_row("row", vec![("cycles".into(), 123.0)]);
        assert_eq!(h.results()[0].metric("cycles"), Some(123.0));
        assert_eq!(h.results()[0].metric("nope"), None);
    }

    #[test]
    fn json_report_carries_stats_and_metrics() {
        let mut h = Harness::new("json-mode");
        h.config.warmup = Duration::from_millis(1);
        h.config.measure = Duration::from_millis(5);
        h.bench("fast-op", || 1 + 1);
        h.metric("extra", 2.5);
        h.metric_row("cycles-row", vec![("cycles".into(), 42.0)]);
        let j = h.to_json();
        assert_eq!(j.get("title").as_str(), Some("json-mode"));
        let results = j.get("results").as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").as_str(), Some("fast-op"));
        assert!(results[0].get("median_s").as_f64().unwrap() >= 0.0);
        assert_eq!(
            results[0].get("metrics").get("extra").as_f64(),
            Some(2.5)
        );
        assert_eq!(
            results[1].get("metrics").get("cycles").as_f64(),
            Some(42.0)
        );
        // Round-trips through the parser (what bench_compare.py reads).
        let text = j.to_string_pretty();
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
