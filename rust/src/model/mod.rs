//! Network model substrate: layer shapes, the explicit topology IR
//! (conv/pool/branch schedules), the five-network zoo the paper
//! evaluates, tensors, and weight sources (synthetic calibrated
//! generators + JAX-trained weight files).

mod io;
mod layer;
pub mod reference;
mod tensor;
pub mod topology;
pub mod weights;
pub mod zoo;

pub use io::{read_weight_file, write_weight_file, LoadedLayer, LoadedWeights};
pub use layer::{ConvLayer, Network};
pub use tensor::Tensor;
pub use topology::{FcSpec, PoolKind, PoolSpec, TopoOp};
