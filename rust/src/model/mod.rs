//! Network model substrate: layer shapes, the five-network zoo the paper
//! evaluates, tensors, and weight sources (synthetic calibrated
//! generators + JAX-trained weight files).

mod io;
mod layer;
mod tensor;
pub mod weights;
pub mod zoo;

pub use io::{read_weight_file, write_weight_file, LoadedLayer, LoadedWeights};
pub use layer::{ConvLayer, Network};
pub use tensor::Tensor;
