//! Convolution layer descriptors and networks.
//!
//! The paper's evaluation is conv-only ("convolutions take nearly 98% of
//! the computations", §I), so MAC/weight accounting sums over each
//! network's ordered conv layers. The *execution order* — including
//! pooling stages and inception branching — is declared explicitly as a
//! [`TopoOp`] schedule (see [`topology`](super::topology)); each layer's
//! recorded `in_hw` is the spatial size the declared schedule delivers
//! to it, which the plan compiler cross-checks at lowering time.

use super::topology::{FcSpec, TopoOp};

/// One convolution layer's shape parameters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    /// Layer name, e.g. `conv3_1` or `inception_4a/3x3`.
    pub name: String,
    /// Input channels.
    pub in_c: usize,
    /// Output channels (filters).
    pub out_c: usize,
    /// Kernel height/width (square kernels throughout the zoo).
    pub k: usize,
    /// Stride.
    pub stride: usize,
    /// Zero padding on each side.
    pub pad: usize,
    /// Input spatial size (square), *after* any preceding pooling.
    pub in_hw: usize,
}

impl ConvLayer {
    /// Output spatial size (square).
    pub fn out_hw(&self) -> usize {
        (self.in_hw + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Weights in this layer (no bias — biases don't enter MAC lanes).
    pub fn weight_count(&self) -> u64 {
        (self.out_c * self.in_c * self.k * self.k) as u64
    }

    /// Multiply-accumulates for one input image.
    pub fn macs(&self) -> u64 {
        self.weight_count() * (self.out_hw() * self.out_hw()) as u64
    }

    /// Reduction ("lane") length for one output pixel of one filter:
    /// in_c × k × k weight/activation pairs summed into one partial sum.
    pub fn lane_len(&self) -> usize {
        self.in_c * self.k * self.k
    }

    /// Number of lanes per image (output pixels × filters).
    pub fn lane_count(&self) -> u64 {
        (self.out_c * self.out_hw() * self.out_hw()) as u64
    }
}

/// A network: named conv layers plus the declared execution schedule
/// ([`TopoOp`]s referencing layers by index).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    pub name: String,
    pub layers: Vec<ConvLayer>,
    /// Declared topology: the order convs, pools and branches execute
    /// in. `TopoOp::Conv(i)` indexes into `layers`.
    pub schedule: Vec<TopoOp>,
}

impl Network {
    /// A plain sequential chain: every conv feeds the next directly,
    /// with no pooling stages (consecutive layers must share spatial
    /// sizes — the plan compiler rejects the schedule otherwise).
    pub fn sequential(name: impl Into<String>, layers: Vec<ConvLayer>) -> Network {
        let schedule = (0..layers.len()).map(TopoOp::Conv).collect();
        Network { name: name.into(), layers, schedule }
    }

    /// A network with an explicitly declared schedule.
    pub fn with_schedule(
        name: impl Into<String>,
        layers: Vec<ConvLayer>,
        schedule: Vec<TopoOp>,
    ) -> Network {
        Network { name: name.into(), layers, schedule }
    }

    /// Conv MACs only — the paper's accounting ("convolutions take
    /// nearly 98% of the computations"). Declared FC heads are summed
    /// separately by [`Network::fc_macs`].
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(ConvLayer::macs).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(ConvLayer::weight_count).sum()
    }

    /// Declared FC classifier layers, schedule order (empty for
    /// conv-only schedules).
    pub fn fc_specs(&self) -> Vec<&FcSpec> {
        self.schedule
            .iter()
            .filter_map(|op| match op {
                TopoOp::Fc(spec) => Some(spec),
                _ => None,
            })
            .collect()
    }

    /// Multiply-accumulates of the declared FC head (one image).
    pub fn fc_macs(&self) -> u64 {
        self.fc_specs().iter().map(|s| s.macs()).sum()
    }

    /// Each declared FC layer as an equivalent 1×1 conv over a 1×1
    /// map (`in_c = in_features`, `out_c = out_features`) — exactly
    /// `in·out` MACs, so the cycle simulators can account for FC
    /// heads with the machinery they already have
    /// (`report::simulate_one` with `include_fc`).
    pub fn fc_as_conv_layers(&self) -> Vec<ConvLayer> {
        self.fc_specs()
            .iter()
            .map(|s| ConvLayer {
                name: s.name.clone(),
                in_c: s.in_features,
                out_c: s.out_features,
                k: 1,
                stride: 1,
                pad: 0,
                in_hw: 1,
            })
            .collect()
    }

    pub fn layer(&self, name: &str) -> Option<&ConvLayer> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Shrunk copy for tests/benches: divide every conv's *output*
    /// channel count by `channel_div` (floor, min 1), then
    /// *re-propagate* both spatial sizes and input channel counts
    /// through the declared schedule — the first executed conv sees
    /// `in_hw` (and its original input channels divided), every later
    /// layer's recorded shape is exactly what the preceding convs,
    /// pools and branch concats produce. Propagating `in_c` (rather
    /// than flooring it independently) keeps branch networks
    /// consistent for *any* divisor: an inception concat of floored
    /// arm widths can sum to less than the floored original, and the
    /// consumer inherits the true sum. Kernel/stride/pad unchanged.
    ///
    /// Panics if `in_hw` is too small for the schedule — i.e. some conv
    /// or pool window would not fit its (padded) input.
    pub fn scaled(&self, channel_div: usize, in_hw: usize) -> Network {
        assert!(channel_div >= 1 && in_hw >= 1);
        let mut layers: Vec<ConvLayer> = self
            .layers
            .iter()
            .map(|l| ConvLayer {
                name: l.name.clone(),
                in_c: l.in_c, // overwritten by propagation below
                out_c: (l.out_c / channel_div).max(1),
                k: l.k,
                stride: l.stride,
                pad: l.pad,
                in_hw: l.in_hw, // overwritten by propagation below
            })
            .collect();
        let entry = self
            .schedule
            .iter()
            .find_map(|op| match op {
                TopoOp::Conv(i) => Some(*i),
                _ => None,
            })
            .unwrap_or(0);
        let in_c = (self.layers.get(entry).map_or(1, |l| l.in_c) / channel_div).max(1);
        let mut schedule = self.schedule.clone();
        // Hidden FC widths scale with the trunk (a ÷16 VGG must not
        // keep 4096-wide fc6/fc7 lanes); the stack's LAST head is a
        // class count and stays unscaled. `in_features` is rewritten
        // by the propagation below.
        let fc_count = schedule.iter().filter(|op| matches!(op, TopoOp::Fc(_))).count();
        let mut fc_i = 0usize;
        for op in schedule.iter_mut() {
            if let TopoOp::Fc(spec) = op {
                fc_i += 1;
                if fc_i < fc_count {
                    spec.out_features = (spec.out_features / channel_div).max(1);
                }
            }
        }
        propagate(&mut schedule, &mut layers, in_c, in_hw, &self.name);
        Network {
            name: format!("{}_div{channel_div}_hw{in_hw}", self.name),
            layers,
            schedule,
        }
    }
}

/// Walk `ops` assigning each conv layer the input shape the schedule
/// delivers to it, starting from `c` channels at `hw`×`hw`; returns
/// the schedule's output shape. Declared `Fc` entries have their
/// `in_features` rewritten to the (flattened) shape the scaled trunk
/// delivers, so scaled copies always re-validate at lowering;
/// `out_features` is a class count and stays unscaled. Panics
/// (test/bench helper semantics) on windows that don't fit.
fn propagate(
    ops: &mut [TopoOp],
    layers: &mut [ConvLayer],
    mut c: usize,
    mut hw: usize,
    net: &str,
) -> (usize, usize) {
    for op in ops {
        match op {
            TopoOp::Conv(i) => {
                let l = &mut layers[*i];
                assert!(
                    hw + 2 * l.pad >= l.k,
                    "{net}: {hw}×{hw} input (pad {}) smaller than `{}`'s {}×{} kernel — pick a larger in_hw",
                    l.pad,
                    l.name,
                    l.k,
                    l.k,
                );
                l.in_c = c;
                l.in_hw = hw;
                c = l.out_c;
                hw = l.out_hw();
            }
            TopoOp::Pool(p) => {
                hw = p
                    .out_hw(hw)
                    .unwrap_or_else(|e| panic!("{net}: {e} — pick a larger in_hw"));
            }
            TopoOp::Branch(arms) => {
                let mut out_c = 0usize;
                let mut out_hw: Option<usize> = None;
                for arm in arms.iter_mut() {
                    let (ac, ahw) = propagate(arm, layers, c, hw, net);
                    out_c += ac;
                    match out_hw {
                        None => out_hw = Some(ahw),
                        Some(h) => assert_eq!(
                            h, ahw,
                            "{net}: branch arms disagree on output spatial size"
                        ),
                    }
                }
                c = out_c;
                hw = out_hw.expect("branch has at least one arm");
            }
            TopoOp::GlobalAvgPool => hw = 1,
            TopoOp::Fc(spec) => {
                // Flatten semantics: an FC after the trunk consumes
                // C·H·W features (H = W = 1 after a GlobalAvgPool or
                // a previous Fc).
                spec.in_features = c * hw * hw;
                c = spec.out_features;
                hw = 1;
            }
        }
    }
    (c, hw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::topology::PoolSpec;

    fn vgg_conv1_1() -> ConvLayer {
        ConvLayer {
            name: "conv1_1".into(),
            in_c: 3,
            out_c: 64,
            k: 3,
            stride: 1,
            pad: 1,
            in_hw: 224,
        }
    }

    #[test]
    fn out_hw_same_padding() {
        assert_eq!(vgg_conv1_1().out_hw(), 224);
    }

    #[test]
    fn out_hw_strided() {
        // AlexNet conv1: 227x227, 11x11, stride 4, pad 0 → 55.
        let l = ConvLayer {
            name: "conv1".into(),
            in_c: 3,
            out_c: 96,
            k: 11,
            stride: 4,
            pad: 0,
            in_hw: 227,
        };
        assert_eq!(l.out_hw(), 55);
    }

    fn two_layer_pooled() -> Network {
        Network::with_schedule(
            "two",
            vec![
                ConvLayer { name: "a".into(), in_c: 16, out_c: 32, k: 3, stride: 1, pad: 1, in_hw: 32 },
                ConvLayer { name: "b".into(), in_c: 32, out_c: 64, k: 3, stride: 1, pad: 1, in_hw: 16 },
            ],
            vec![TopoOp::Conv(0), TopoOp::Pool(PoolSpec::max(2, 2, 0)), TopoOp::Conv(1)],
        )
    }

    #[test]
    fn scaled_keeps_chain_and_pool_ratios() {
        let net = two_layer_pooled();
        let s = net.scaled(8, 8);
        assert_eq!(s.layers[0].in_c, 2);
        assert_eq!(s.layers[0].out_c, s.layers[1].in_c);
        // Pool stage re-propagated: 32→16 becomes 8→4.
        assert_eq!(s.layers[0].in_hw, 8);
        assert_eq!(s.layers[1].in_hw, 4);
        // The declared schedule survives scaling untouched.
        assert_eq!(s.schedule, net.schedule);
        // Divisor larger than a channel count floors to 1.
        assert_eq!(net.scaled(1000, 8).layers[0].in_c, 1);
    }

    #[test]
    fn scaled_propagates_strided_and_ceil_pools() {
        // AlexNet-shaped head: 11×11 stride-4 conv + 3×3 stride-2 pool.
        let net = Network::with_schedule(
            "mini_alex",
            vec![
                ConvLayer { name: "c1".into(), in_c: 3, out_c: 8, k: 11, stride: 4, pad: 0, in_hw: 227 },
                ConvLayer { name: "c2".into(), in_c: 8, out_c: 8, k: 5, stride: 1, pad: 2, in_hw: 27 },
            ],
            vec![TopoOp::Conv(0), TopoOp::Pool(PoolSpec::max(3, 2, 0)), TopoOp::Conv(1)],
        );
        let s = net.scaled(1, 63);
        // (63-11)/4+1 = 14, pool ceil((14-3)/2)+1 = 7.
        assert_eq!(s.layers[0].in_hw, 63);
        assert_eq!(s.layers[0].out_hw(), 14);
        assert_eq!(s.layers[1].in_hw, 7);
    }

    #[test]
    #[should_panic(expected = "smaller than")]
    fn scaled_rejects_windows_larger_than_input() {
        // Target in_hw 1 leaves the 2×2 pool without a full window.
        let _ = two_layer_pooled().scaled(1, 1);
    }

    #[test]
    fn macs_and_lanes_consistent() {
        let l = vgg_conv1_1();
        // total MACs == lanes × lane length
        assert_eq!(l.macs(), l.lane_count() * l.lane_len() as u64);
        // known value: 64*3*3*3*224*224 = 86,704,128
        assert_eq!(l.macs(), 86_704_128);
    }

    #[test]
    fn fc_specs_account_macs_and_scale() {
        // conv (16→32 at 32², pooled to 16²) → flatten fc → class fc.
        let net = Network::with_schedule(
            "with_head",
            vec![ConvLayer { name: "a".into(), in_c: 16, out_c: 32, k: 3, stride: 1, pad: 1, in_hw: 32 }],
            vec![
                TopoOp::Conv(0),
                TopoOp::Pool(PoolSpec::max(2, 2, 0)),
                TopoOp::Fc(FcSpec::new("fc6", 32 * 16 * 16, 100)),
                TopoOp::Fc(FcSpec::new("fc7", 100, 10)),
            ],
        );
        assert_eq!(net.fc_specs().len(), 2);
        assert_eq!(net.fc_macs(), (32 * 16 * 16 * 100 + 100 * 10) as u64);
        // Conv accounting stays conv-only.
        assert_eq!(net.total_macs(), net.layers[0].macs());
        // The 1×1-conv equivalents carry exactly the FC MACs.
        let eq = net.fc_as_conv_layers();
        assert_eq!(eq.len(), 2);
        assert_eq!(eq.iter().map(ConvLayer::macs).sum::<u64>(), net.fc_macs());
        assert!(eq.iter().all(|l| l.k == 1 && l.in_hw == 1 && l.out_hw() == 1));
        // Scaling rewrites in_features to what the scaled trunk
        // delivers (out_c 32/4 = 8, pooled 8² map → 8·64), shrinks
        // hidden widths with the trunk (100/4 = 25), and chains
        // through the head — leaving the final class count alone.
        let s = net.scaled(4, 16);
        let specs = s.fc_specs();
        assert_eq!(specs[0].in_features, 8 * 8 * 8);
        assert_eq!(specs[0].out_features, 25);
        assert_eq!(specs[1].in_features, 25);
        assert_eq!(specs[1].out_features, 10);
    }

    #[test]
    fn sequential_schedules_every_layer_in_order() {
        let net = Network::sequential(
            "chain",
            vec![vgg_conv1_1(), ConvLayer { name: "conv1_2".into(), in_c: 64, out_c: 64, k: 3, stride: 1, pad: 1, in_hw: 224 }],
        );
        assert_eq!(net.schedule, vec![TopoOp::Conv(0), TopoOp::Conv(1)]);
    }
}
