//! Convolution layer descriptors and networks.
//!
//! The paper's evaluation is conv-only ("convolutions take nearly 98% of
//! the computations", §I), so the zoo describes each network as its
//! ordered conv layers; pooling only enters via each layer's recorded
//! input spatial size.

/// One convolution layer's shape parameters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    /// Layer name, e.g. `conv3_1` or `inception_4a/3x3`.
    pub name: String,
    /// Input channels.
    pub in_c: usize,
    /// Output channels (filters).
    pub out_c: usize,
    /// Kernel height/width (square kernels throughout the zoo).
    pub k: usize,
    /// Stride.
    pub stride: usize,
    /// Zero padding on each side.
    pub pad: usize,
    /// Input spatial size (square), *after* any preceding pooling.
    pub in_hw: usize,
}

impl ConvLayer {
    /// Output spatial size (square).
    pub fn out_hw(&self) -> usize {
        (self.in_hw + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Weights in this layer (no bias — biases don't enter MAC lanes).
    pub fn weight_count(&self) -> u64 {
        (self.out_c * self.in_c * self.k * self.k) as u64
    }

    /// Multiply-accumulates for one input image.
    pub fn macs(&self) -> u64 {
        self.weight_count() * (self.out_hw() * self.out_hw()) as u64
    }

    /// Reduction ("lane") length for one output pixel of one filter:
    /// in_c × k × k weight/activation pairs summed into one partial sum.
    pub fn lane_len(&self) -> usize {
        self.in_c * self.k * self.k
    }

    /// Number of lanes per image (output pixels × filters).
    pub fn lane_count(&self) -> u64 {
        (self.out_c * self.out_hw() * self.out_hw()) as u64
    }
}

/// A network = named ordered list of conv layers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    pub name: String,
    pub layers: Vec<ConvLayer>,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(ConvLayer::macs).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(ConvLayer::weight_count).sum()
    }

    pub fn layer(&self, name: &str) -> Option<&ConvLayer> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Shrunk copy for tests/benches: divide every channel count by
    /// `channel_div` (floor, min 1 — the chain stays consistent because
    /// all counts scale by the same divisor) and rescale spatial sizes
    /// so the first layer's input becomes `in_hw` (later layers keep
    /// their pooling ratio to the first). Kernel/stride/pad unchanged.
    ///
    /// Panics if `in_hw` is too small to keep the pooling schedule:
    /// scaling must not collapse two layers with *different* original
    /// spatial sizes onto the same value, or the derived plan graph
    /// would silently lose a pool stage.
    pub fn scaled(&self, channel_div: usize, in_hw: usize) -> Network {
        assert!(channel_div >= 1 && in_hw >= 1);
        let base_hw = match self.layers.first() {
            Some(l) => l.in_hw,
            None => return self.clone(),
        };
        let scale = |hw: usize| (hw * in_hw / base_hw).max(1);
        for pair in self.layers.windows(2) {
            assert!(
                pair[0].in_hw == pair[1].in_hw || scale(pair[0].in_hw) != scale(pair[1].in_hw),
                "{}: in_hw={in_hw} collapses the {}→{} pool stage ({}→{}); pick a larger in_hw",
                self.name,
                pair[0].name,
                pair[1].name,
                pair[0].in_hw,
                pair[1].in_hw,
            );
        }
        let layers = self
            .layers
            .iter()
            .map(|l| ConvLayer {
                name: l.name.clone(),
                in_c: (l.in_c / channel_div).max(1),
                out_c: (l.out_c / channel_div).max(1),
                k: l.k,
                stride: l.stride,
                pad: l.pad,
                in_hw: scale(l.in_hw),
            })
            .collect();
        Network { name: format!("{}_div{channel_div}_hw{in_hw}", self.name), layers }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vgg_conv1_1() -> ConvLayer {
        ConvLayer {
            name: "conv1_1".into(),
            in_c: 3,
            out_c: 64,
            k: 3,
            stride: 1,
            pad: 1,
            in_hw: 224,
        }
    }

    #[test]
    fn out_hw_same_padding() {
        assert_eq!(vgg_conv1_1().out_hw(), 224);
    }

    #[test]
    fn out_hw_strided() {
        // AlexNet conv1: 227x227, 11x11, stride 4, pad 0 → 55.
        let l = ConvLayer {
            name: "conv1".into(),
            in_c: 3,
            out_c: 96,
            k: 11,
            stride: 4,
            pad: 0,
            in_hw: 227,
        };
        assert_eq!(l.out_hw(), 55);
    }

    #[test]
    fn scaled_keeps_chain_and_pool_ratios() {
        let net = Network {
            name: "two".into(),
            layers: vec![
                ConvLayer { name: "a".into(), in_c: 16, out_c: 32, k: 3, stride: 1, pad: 1, in_hw: 32 },
                ConvLayer { name: "b".into(), in_c: 32, out_c: 64, k: 3, stride: 1, pad: 1, in_hw: 16 },
            ],
        };
        let s = net.scaled(8, 8);
        assert_eq!(s.layers[0].in_c, 2);
        assert_eq!(s.layers[0].out_c, s.layers[1].in_c);
        // Pool ratio preserved: 32→16 becomes 8→4.
        assert_eq!(s.layers[0].in_hw, 8);
        assert_eq!(s.layers[1].in_hw, 4);
        // Divisor larger than a channel count floors to 1.
        assert_eq!(net.scaled(1000, 8).layers[0].in_c, 1);
    }

    #[test]
    #[should_panic(expected = "collapses")]
    fn scaled_rejects_pool_collapsing_sizes() {
        // Target in_hw 1 maps both 32 and 16 to 1, losing the pool.
        let net = Network {
            name: "two".into(),
            layers: vec![
                ConvLayer { name: "a".into(), in_c: 4, out_c: 4, k: 3, stride: 1, pad: 1, in_hw: 32 },
                ConvLayer { name: "b".into(), in_c: 4, out_c: 4, k: 3, stride: 1, pad: 1, in_hw: 16 },
            ],
        };
        let _ = net.scaled(1, 1);
    }

    #[test]
    fn macs_and_lanes_consistent() {
        let l = vgg_conv1_1();
        // total MACs == lanes × lane length
        assert_eq!(l.macs(), l.lane_count() * l.lane_len() as u64);
        // known value: 64*3*3*3*224*224 = 86,704,128
        assert_eq!(l.macs(), 86_704_128);
    }
}
