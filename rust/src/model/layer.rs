//! Convolution layer descriptors and networks.
//!
//! The paper's evaluation is conv-only ("convolutions take nearly 98% of
//! the computations", §I), so the zoo describes each network as its
//! ordered conv layers; pooling only enters via each layer's recorded
//! input spatial size.

/// One convolution layer's shape parameters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    /// Layer name, e.g. `conv3_1` or `inception_4a/3x3`.
    pub name: String,
    /// Input channels.
    pub in_c: usize,
    /// Output channels (filters).
    pub out_c: usize,
    /// Kernel height/width (square kernels throughout the zoo).
    pub k: usize,
    /// Stride.
    pub stride: usize,
    /// Zero padding on each side.
    pub pad: usize,
    /// Input spatial size (square), *after* any preceding pooling.
    pub in_hw: usize,
}

impl ConvLayer {
    /// Output spatial size (square).
    pub fn out_hw(&self) -> usize {
        (self.in_hw + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Weights in this layer (no bias — biases don't enter MAC lanes).
    pub fn weight_count(&self) -> u64 {
        (self.out_c * self.in_c * self.k * self.k) as u64
    }

    /// Multiply-accumulates for one input image.
    pub fn macs(&self) -> u64 {
        self.weight_count() * (self.out_hw() * self.out_hw()) as u64
    }

    /// Reduction ("lane") length for one output pixel of one filter:
    /// in_c × k × k weight/activation pairs summed into one partial sum.
    pub fn lane_len(&self) -> usize {
        self.in_c * self.k * self.k
    }

    /// Number of lanes per image (output pixels × filters).
    pub fn lane_count(&self) -> u64 {
        (self.out_c * self.out_hw() * self.out_hw()) as u64
    }
}

/// A network = named ordered list of conv layers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Network {
    pub name: String,
    pub layers: Vec<ConvLayer>,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(ConvLayer::macs).sum()
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(ConvLayer::weight_count).sum()
    }

    pub fn layer(&self, name: &str) -> Option<&ConvLayer> {
        self.layers.iter().find(|l| l.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vgg_conv1_1() -> ConvLayer {
        ConvLayer {
            name: "conv1_1".into(),
            in_c: 3,
            out_c: 64,
            k: 3,
            stride: 1,
            pad: 1,
            in_hw: 224,
        }
    }

    #[test]
    fn out_hw_same_padding() {
        assert_eq!(vgg_conv1_1().out_hw(), 224);
    }

    #[test]
    fn out_hw_strided() {
        // AlexNet conv1: 227x227, 11x11, stride 4, pad 0 → 55.
        let l = ConvLayer {
            name: "conv1".into(),
            in_c: 3,
            out_c: 96,
            k: 11,
            stride: 4,
            pad: 0,
            in_hw: 227,
        };
        assert_eq!(l.out_hw(), 55);
    }

    #[test]
    fn macs_and_lanes_consistent() {
        let l = vgg_conv1_1();
        // total MACs == lanes × lane length
        assert_eq!(l.macs(), l.lane_count() * l.lane_len() as u64);
        // known value: 64*3*3*3*224*224 = 86,704,128
        assert_eq!(l.macs(), 86_704_128);
    }
}
