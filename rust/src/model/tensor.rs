//! Minimal dense tensor (NCHW) used by the functional inference path and
//! the runtime golden-model comparison.

/// Dense row-major tensor over `T`.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

impl<T: Copy + Default> Tensor<T> {
    /// Zero-initialized tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    /// Wrap existing data; errors if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> crate::Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(crate::Error::Shape(format!(
                "shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Self { shape: shape.to_vec(), data })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<T> {
        self.data
    }

    /// Flat index for a 4-D (NCHW) coordinate.
    #[inline]
    pub fn idx4(&self, n: usize, c: usize, h: usize, w: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((n * self.shape[1] + c) * self.shape[2] + h) * self.shape[3] + w
    }

    #[inline]
    pub fn get4(&self, n: usize, c: usize, h: usize, w: usize) -> T {
        self.data[self.idx4(n, c, h, w)]
    }

    #[inline]
    pub fn set4(&mut self, n: usize, c: usize, h: usize, w: usize, v: T) {
        let i = self.idx4(n, c, h, w);
        self.data[i] = v;
    }

    /// Reshape in place (same element count).
    pub fn reshape(&mut self, shape: &[usize]) -> crate::Result<()> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(crate::Error::Shape(format!(
                "cannot reshape {} elements into {:?}",
                self.data.len(),
                shape
            )));
        }
        self.shape = shape.to_vec();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_indexing() {
        let mut t: Tensor<i32> = Tensor::zeros(&[1, 2, 3, 4]);
        assert_eq!(t.len(), 24);
        t.set4(0, 1, 2, 3, 7);
        assert_eq!(t.get4(0, 1, 2, 3), 7);
        assert_eq!(t.data()[23], 7); // last element in row-major NCHW
    }

    #[test]
    fn from_vec_validates() {
        assert!(Tensor::from_vec(&[2, 3], vec![0i32; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0i32; 5]).is_err());
    }

    #[test]
    fn reshape_checks_count() {
        let mut t: Tensor<f32> = Tensor::zeros(&[4, 4]);
        assert!(t.reshape(&[2, 8]).is_ok());
        assert_eq!(t.shape(), &[2, 8]);
        assert!(t.reshape(&[3, 3]).is_err());
    }
}
