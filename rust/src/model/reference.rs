//! Naive scalar reference interpreter of a declared topology — the
//! MAC-side baseline of invariant I5 (DESIGN.md).
//!
//! Walks `Network::schedule` with plain i64 MAC loops, per-window pool
//! scans, an element-wise channel concat and a floor-divide global
//! average pool. It deliberately shares **no** execution code with
//! `plan::exec` (different gather strategy, no kneading, no SAC, no
//! slice-copy concat): the plan executor is property-tested bit-exact
//! against this independent implementation across the full zoo
//! (`rust/tests/plan_topology.rs`) and benchmarked against it as the
//! `forward_scalar`-style baseline (`benches/hotpath.rs`). Keeping one
//! shared reference for both consumers means the definition of
//! "correct" cannot drift between the test suite and the bench.
//!
//! Scope: every conv fuses ReLU + requantization (matching the
//! lowered `Conv → ReluRequant` pair), pools follow the Caffe
//! ceil-mode geometry, and schedule-declared `Fc` stacks execute
//! naively when the weight set carries **every** head (flatten the
//! trunk, i64 MAC per output feature, ReLU + requantization on every
//! head but the last — exactly the plan compiler's lowering), so I5
//! bit-exactness extends to logits-after-fc. A stack with **no**
//! weighted head stays declaration-only accounting topology (skipped,
//! like the plan compiler does); a mixed stack panics. Implicit `fc`
//! weight layers with no declared head are exercised through the
//! tiny-CNN legacy reference (`runtime::quantized::forward_scalar`)
//! instead.

use crate::quant::requantize;

use super::layer::Network;
use super::io::{LoadedLayer, LoadedWeights};
use super::tensor::Tensor;
use super::topology::{PoolKind, PoolSpec, TopoOp};

/// Plain integer MAC conv: i64 accumulate, one truncating `as i32`
/// cast per output — the exact contract SAC lanes must reproduce.
fn ref_conv(x: &Tensor<i32>, wl: &LoadedLayer, pad: usize, stride: usize) -> Tensor<i32> {
    let [o, c, kh, kw] = wl.shape;
    let (n, h, w) = match *x.shape() {
        [n, cx, h, w] => {
            assert_eq!(cx, c, "{}: channel mismatch", wl.name);
            (n, h, w)
        }
        _ => panic!("4-D input"),
    };
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let mut out: Tensor<i32> = Tensor::zeros(&[n, o, oh, ow]);
    let lane = c * kh * kw;
    for b in 0..n {
        for f in 0..o {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    for cc in 0..c {
                        for ky in 0..kh {
                            for kx in 0..kw {
                                let (iy, ix) = (oy * stride + ky, ox * stride + kx);
                                if iy < pad || ix < pad || iy - pad >= h || ix - pad >= w {
                                    continue;
                                }
                                let wv = wl.weights[f * lane + (cc * kh + ky) * kw + kx] as i64;
                                acc += wv * x.get4(b, cc, iy - pad, ix - pad) as i64;
                            }
                        }
                    }
                    out.set4(b, f, oy, ox, acc as i32);
                }
            }
        }
    }
    out
}

/// Caffe ceil-mode pool extent (same arithmetic `PoolSpec::out_hw`
/// pins — re-stated here so the reference stands alone).
fn ref_pool_extent(in_hw: usize, k: usize, stride: usize, pad: usize) -> usize {
    let padded = in_hw + 2 * pad;
    assert!(padded >= k && pad < k, "degenerate pool window");
    let mut out = (padded - k).div_ceil(stride) + 1;
    if (out - 1) * stride >= in_hw + pad {
        out -= 1;
    }
    out
}

/// Naive pool: per-window scan over the in-bounds taps (max ignores
/// padding; avg floor-divides by the in-bounds tap count).
fn ref_pool(x: &Tensor<i32>, spec: PoolSpec) -> Tensor<i32> {
    let [n, c, h, w] = match *x.shape() {
        [n, c, h, w] => [n, c, h, w],
        _ => panic!("4-D input"),
    };
    let (k, s, p) = (spec.k, spec.stride, spec.pad);
    let (oh, ow) = (ref_pool_extent(h, k, s, p), ref_pool_extent(w, k, s, p));
    let mut out: Tensor<i32> = Tensor::zeros(&[n, c, oh, ow]);
    for b in 0..n {
        for cc in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc: Option<i64> = None;
                    let mut taps = 0i64;
                    for ky in 0..k {
                        for kx in 0..k {
                            let (iy, ix) = (oy * s + ky, ox * s + kx);
                            if iy < p || ix < p || iy - p >= h || ix - p >= w {
                                continue; // padding tap: excluded
                            }
                            let v = x.get4(b, cc, iy - p, ix - p) as i64;
                            taps += 1;
                            acc = Some(match (spec.kind, acc) {
                                (PoolKind::Max, None) => v,
                                (PoolKind::Max, Some(m)) => m.max(v),
                                (PoolKind::Avg, None) => v,
                                (PoolKind::Avg, Some(sum)) => sum + v,
                            });
                        }
                    }
                    let v = match spec.kind {
                        PoolKind::Max => acc.expect("non-empty window"),
                        PoolKind::Avg => acc.expect("non-empty window").div_euclid(taps),
                    };
                    out.set4(b, cc, oy, ox, v as i32);
                }
            }
        }
    }
    out
}

/// Element-wise channel concat (the plan executor uses slice copies).
fn ref_concat(parts: &[Tensor<i32>]) -> Tensor<i32> {
    let [n, _, h, w] = match *parts[0].shape() {
        [n, c, h, w] => [n, c, h, w],
        _ => panic!("4-D input"),
    };
    let total_c: usize = parts.iter().map(|p| p.shape()[1]).sum();
    let mut out: Tensor<i32> = Tensor::zeros(&[n, total_c, h, w]);
    let mut c_off = 0;
    for p in parts {
        let pc = p.shape()[1];
        for b in 0..n {
            for cc in 0..pc {
                for y in 0..h {
                    for xx in 0..w {
                        out.set4(b, c_off + cc, y, xx, p.get4(b, cc, y, xx));
                    }
                }
            }
        }
        c_off += pc;
    }
    out
}

/// Global average pool: i64 sum, floor division, (N,C,H,W) → (N,C).
fn ref_gap(x: &Tensor<i32>) -> Tensor<i32> {
    let [n, c, h, w] = match *x.shape() {
        [n, c, h, w] => [n, c, h, w],
        _ => panic!("4-D input"),
    };
    let mut out: Tensor<i32> = Tensor::zeros(&[n, c]);
    for b in 0..n {
        for cc in 0..c {
            let mut s = 0i64;
            for y in 0..h {
                for xx in 0..w {
                    s += x.get4(b, cc, y, xx) as i64;
                }
            }
            out.data_mut()[b * c + cc] = s.div_euclid((h * w) as i64) as i32;
        }
    }
    out
}

/// Naive FC layer: flatten the input to (N, feat) if spatial, then one
/// i64 MAC accumulation per output feature (row-major weight gather —
/// the same order the plan's FC lanes were kneaded in), one truncating
/// `as i32` cast, and — for every head but the stack's last — the same
/// fused ReLU + requantization a conv applies.
fn ref_fc(x: &Tensor<i32>, wl: &LoadedLayer, relu: bool) -> Tensor<i32> {
    let (n, feat) = match *x.shape() {
        [n, c, h, w] => (n, c * h * w),
        [n, d] => (n, d),
        _ => panic!("FC input must be 2-D or 4-D"),
    };
    let out_f = wl.shape[0];
    let in_f = wl.shape[1] * wl.shape[2] * wl.shape[3];
    assert_eq!(feat, in_f, "{}: trunk delivers {feat}, weights reduce {in_f}", wl.name);
    let mut out: Tensor<i32> = Tensor::zeros(&[n, out_f]);
    for b in 0..n {
        let feats = &x.data()[b * feat..(b + 1) * feat];
        for o in 0..out_f {
            let mut acc = 0i64;
            for (i, &a) in feats.iter().enumerate() {
                acc += wl.weights[o * in_f + i] as i64 * a as i64;
            }
            let mut v = acc as i32;
            if relu {
                v = requantize(v, wl.frac_bits).max(0);
            }
            out.data_mut()[b * out_f + o] = v;
        }
    }
    out
}

fn ref_ops(
    ops: &[TopoOp],
    net: &Network,
    w: &LoadedWeights,
    mut h: Tensor<i32>,
    fc_seen: &mut usize,
    fc_weighted: usize,
) -> Tensor<i32> {
    for op in ops {
        h = match op {
            TopoOp::Conv(i) => {
                let l = &net.layers[*i];
                let wl = w.layer(&l.name).expect("weights for scheduled layer");
                let mut acc = ref_conv(&h, wl, l.pad, l.stride);
                for v in acc.data_mut() {
                    *v = requantize(*v, wl.frac_bits).max(0);
                }
                acc
            }
            TopoOp::Pool(p) => ref_pool(&h, *p),
            TopoOp::Branch(arms) => {
                let parts: Vec<Tensor<i32>> = arms
                    .iter()
                    .map(|a| ref_ops(a, net, w, h.clone(), fc_seen, fc_weighted))
                    .collect();
                ref_concat(&parts)
            }
            TopoOp::GlobalAvgPool => ref_gap(&h),
            TopoOp::Fc(spec) => match w.layer(&spec.name) {
                // Declaration-only heads (no weights) are accounting
                // topology: the reference result is the conv trunk,
                // mirroring the plan compiler's lowering.
                None => {
                    assert_eq!(
                        fc_weighted, 0,
                        "fc stack mixes weighted and weightless heads at `{}`",
                        spec.name
                    );
                    h
                }
                Some(fl) => {
                    *fc_seen += 1;
                    ref_fc(&h, fl, *fc_seen < fc_weighted)
                }
            },
        };
    }
    h
}

/// Interpret `net`'s declared schedule naively over a Q8.8 batch.
/// Weight sets are conv-only (the trunk is the result), or carry every
/// declared FC head (image → logits); implicit appended `fc` heads go
/// through the legacy tiny-CNN reference instead.
pub fn forward_reference(net: &Network, w: &LoadedWeights, x: &Tensor<i32>) -> Tensor<i32> {
    let fc_weighted = net
        .fc_specs()
        .iter()
        .filter(|s| w.layer(&s.name).is_some())
        .count();
    let mut fc_seen = 0usize;
    ref_ops(&net.schedule, net, w, x.clone(), &mut fc_seen, fc_weighted)
}
