//! The five-network zoo of the paper's evaluation (§IV): AlexNet,
//! GoogleNet, VGG-16, VGG-19 and NiN — conv layers plus each network's
//! *declared* execution schedule (pooling stages, inception branching,
//! NiN's global-average head).
//!
//! Shapes and schedules follow the canonical Caffe Model Zoo prototxts
//! the paper cites: AlexNet/NiN pool 3×3 stride 2, VGG pools 2×2
//! stride 2 after every block, GoogleNet interleaves 3×3 stride-2
//! pools (ceil mode) with its nine four-arm inception modules.

use super::layer::{ConvLayer, Network};
use super::topology::{FcSpec, PoolSpec, TopoOp};

fn conv(name: &str, in_c: usize, out_c: usize, k: usize, stride: usize, pad: usize, in_hw: usize) -> ConvLayer {
    ConvLayer { name: name.to_string(), in_c, out_c, k, stride, pad, in_hw }
}

/// The max-pool geometry shared by AlexNet, NiN and GoogleNet.
fn pool3s2() -> TopoOp {
    TopoOp::Pool(PoolSpec::max(3, 2, 0))
}

/// AlexNet (single-tower Caffe variant): 5 conv layers, 3×3 stride-2
/// max pools after conv1, conv2 and conv5.
pub fn alexnet() -> Network {
    Network::with_schedule(
        "alexnet",
        vec![
            conv("conv1", 3, 96, 11, 4, 0, 227),
            conv("conv2", 96, 256, 5, 1, 2, 27),
            conv("conv3", 256, 384, 3, 1, 1, 13),
            conv("conv4", 384, 384, 3, 1, 1, 13),
            conv("conv5", 384, 256, 3, 1, 1, 13),
        ],
        vec![
            TopoOp::Conv(0), // 227 → 55
            pool3s2(),       // 55 → 27
            TopoOp::Conv(1),
            pool3s2(), // 27 → 13
            TopoOp::Conv(2),
            TopoOp::Conv(3),
            TopoOp::Conv(4),
            pool3s2(), // 13 → 6
        ],
    )
}

/// The VGG conv stack shared by VGG-16 and VGG-19: `n` convs per block,
/// a 2×2 stride-2 max pool after every block, then the published
/// classifier head — fc6/fc7/fc8 over the flattened 512×7×7 block-5
/// output. The head is declared topology ([`FcSpec`]): MAC/weight
/// accounting and shape validation always; executable image → logits
/// when the weight set carries all three layers (e.g.
/// `model::weights::synthetic_loaded_with_heads`), conv-trunk serving
/// otherwise.
fn vgg(name: &str, blocks: &[(usize, usize, usize, usize, usize)]) -> Network {
    let mut layers = Vec::new();
    let mut schedule = Vec::new();
    for &(b, n, in_c, out_c, hw) in blocks {
        for i in 1..=n {
            let ic = if i == 1 { in_c } else { out_c };
            schedule.push(TopoOp::Conv(layers.len()));
            layers.push(conv(&format!("conv{b}_{i}"), ic, out_c, 3, 1, 1, hw));
        }
        schedule.push(TopoOp::Pool(PoolSpec::max(2, 2, 0)));
    }
    schedule.push(TopoOp::Fc(FcSpec::new("fc6", 512 * 7 * 7, 4096)));
    schedule.push(TopoOp::Fc(FcSpec::new("fc7", 4096, 4096)));
    schedule.push(TopoOp::Fc(FcSpec::new("fc8", 4096, 1000)));
    Network::with_schedule(name, layers, schedule)
}

/// VGG-16: 13 conv layers, all 3×3 pad 1; 5 pools.
pub fn vgg16() -> Network {
    // (block, convs, in_c, out_c, in_hw)
    vgg(
        "vgg16",
        &[
            (1, 2, 3, 64, 224),
            (2, 2, 64, 128, 112),
            (3, 3, 128, 256, 56),
            (4, 3, 256, 512, 28),
            (5, 3, 512, 512, 14),
        ],
    )
}

/// One pooling block of VGG-16 as a standalone chain network — the
/// plan executor's canonical non-tiny sequential workload (`conv{b}_1..`
/// layers, all 3×3 stride-1 pad-1, same spatial size within the block).
pub fn vgg16_block(block: usize) -> crate::Result<Network> {
    let prefix = format!("conv{block}_");
    let layers: Vec<ConvLayer> = vgg16()
        .layers
        .into_iter()
        .filter(|l| l.name.starts_with(&prefix))
        .collect();
    if layers.is_empty() {
        return Err(crate::Error::Config(format!(
            "vgg16 has no block {block} (want 1..=5)"
        )));
    }
    Ok(Network::sequential(format!("vgg16_block{block}"), layers))
}

/// VGG-19: 16 conv layers (blocks 3–5 have four convs); 5 pools.
pub fn vgg19() -> Network {
    vgg(
        "vgg19",
        &[
            (1, 2, 3, 64, 224),
            (2, 2, 64, 128, 112),
            (3, 4, 128, 256, 56),
            (4, 4, 256, 512, 28),
            (5, 4, 512, 512, 14),
        ],
    )
}

/// Network-in-Network (ImageNet): 4 conv + 8 cccp (1×1 conv) layers,
/// 3×3 stride-2 max pools between the mlpconv stacks and a global
/// average pool head (no FC — cccp8's 1000 channels are the logits).
pub fn nin() -> Network {
    let layers = vec![
        conv("conv1", 3, 96, 11, 4, 0, 227),
        conv("cccp1", 96, 96, 1, 1, 0, 55),
        conv("cccp2", 96, 96, 1, 1, 0, 55),
        conv("conv2", 96, 256, 5, 1, 2, 27),
        conv("cccp3", 256, 256, 1, 1, 0, 27),
        conv("cccp4", 256, 256, 1, 1, 0, 27),
        conv("conv3", 256, 384, 3, 1, 1, 13),
        conv("cccp5", 384, 384, 1, 1, 0, 13),
        conv("cccp6", 384, 384, 1, 1, 0, 13),
        conv("conv4-1024", 384, 1024, 3, 1, 1, 6),
        conv("cccp7", 1024, 1024, 1, 1, 0, 6),
        conv("cccp8", 1024, 1000, 1, 1, 0, 6),
    ];
    let mut schedule = Vec::new();
    for (stack, end) in [(0usize..3, true), (3..6, true), (6..9, true), (9..12, false)] {
        for i in stack {
            schedule.push(TopoOp::Conv(i));
        }
        if end {
            schedule.push(pool3s2()); // 55 → 27 → 13 → 6
        }
    }
    schedule.push(TopoOp::GlobalAvgPool); // Caffe pool4: 6×6 global ave
    Network::with_schedule("nin", layers, schedule)
}

/// One inception module's spec:
/// (name, in_c, hw, n1x1, n3x3r, n3x3, n5x5r, n5x5, pool_proj).
type InceptionSpec = (&'static str, usize, usize, usize, usize, usize, usize, usize, usize);

/// GoogleNet's nine inception modules.
const INCEPTION_MODULES: [InceptionSpec; 9] = [
    ("3a", 192, 28, 64, 96, 128, 16, 32, 32),
    ("3b", 256, 28, 128, 128, 192, 32, 96, 64),
    ("4a", 480, 14, 192, 96, 208, 16, 48, 64),
    ("4b", 512, 14, 160, 112, 224, 24, 64, 64),
    ("4c", 512, 14, 128, 128, 256, 24, 64, 64),
    ("4d", 512, 14, 112, 144, 288, 32, 64, 64),
    ("4e", 528, 14, 256, 160, 320, 32, 128, 128),
    ("5a", 832, 7, 256, 160, 320, 32, 128, 128),
    ("5b", 832, 7, 384, 192, 384, 48, 128, 128),
];

/// Push one inception module's six conv layers; returns the index of
/// its first layer (the 1×1 arm).
fn push_inception_layers(
    layers: &mut Vec<ConvLayer>,
    (m, in_c, hw, n1, n3r, n3, n5r, n5, pp): InceptionSpec,
) -> usize {
    let base = layers.len();
    layers.push(conv(&format!("inception_{m}/1x1"), in_c, n1, 1, 1, 0, hw));
    layers.push(conv(&format!("inception_{m}/3x3_reduce"), in_c, n3r, 1, 1, 0, hw));
    layers.push(conv(&format!("inception_{m}/3x3"), n3r, n3, 3, 1, 1, hw));
    layers.push(conv(&format!("inception_{m}/5x5_reduce"), in_c, n5r, 1, 1, 0, hw));
    layers.push(conv(&format!("inception_{m}/5x5"), n5r, n5, 5, 1, 2, hw));
    layers.push(conv(&format!("inception_{m}/pool_proj"), in_c, pp, 1, 1, 0, hw));
    base
}

/// The four-arm branch of an inception module whose first layer sits at
/// `base`: 1×1 | 1×1→3×3 | 1×1→5×5 | 3×3-s1-pool→1×1, concatenated
/// along channels in that (Caffe concat) order.
fn inception_branch(base: usize) -> TopoOp {
    TopoOp::Branch(vec![
        vec![TopoOp::Conv(base)],
        vec![TopoOp::Conv(base + 1), TopoOp::Conv(base + 2)],
        vec![TopoOp::Conv(base + 3), TopoOp::Conv(base + 4)],
        vec![TopoOp::Pool(PoolSpec::max(3, 1, 1)), TopoOp::Conv(base + 5)],
    ])
}

/// GoogleNet (Inception v1): stem + 9 inception modules = 57 conv
/// layers; 3×3 stride-2 ceil-mode pools after the stem, after module
/// 3b and after module 4e; global average pool head.
pub fn googlenet() -> Network {
    let mut layers = vec![
        conv("conv1/7x7_s2", 3, 64, 7, 2, 3, 224),
        conv("conv2/3x3_reduce", 64, 64, 1, 1, 0, 56),
        conv("conv2/3x3", 64, 192, 3, 1, 1, 56),
    ];
    let mut schedule = vec![
        TopoOp::Conv(0), // 224 → 112
        pool3s2(),       // 112 → 56
        TopoOp::Conv(1),
        TopoOp::Conv(2),
        pool3s2(), // 56 → 28
    ];
    for module in INCEPTION_MODULES {
        let base = push_inception_layers(&mut layers, module);
        schedule.push(inception_branch(base));
        if module.0 == "3b" || module.0 == "4e" {
            schedule.push(pool3s2()); // 28 → 14, 14 → 7
        }
    }
    schedule.push(TopoOp::GlobalAvgPool); // Caffe pool5: 7×7 global ave
    // Declared classifier head (1024 pooled channels → 1000 classes);
    // accounting topology — see the `vgg` head note.
    schedule.push(TopoOp::Fc(FcSpec::new("loss3/classifier", 1024, 1000)));
    Network::with_schedule("googlenet", layers, schedule)
}

/// One GoogleNet inception module as a standalone network: a 1×1
/// identity-shaped stem conv feeding the module's four arms. The
/// plan executor's canonical branching workload for tests/benches.
pub fn inception_module(m: &str) -> crate::Result<Network> {
    let module = INCEPTION_MODULES
        .into_iter()
        .find(|spec| spec.0 == m)
        .ok_or_else(|| {
            crate::Error::Config(format!(
                "unknown inception module `{m}` (want 3a|3b|4a|4b|4c|4d|4e|5a|5b)"
            ))
        })?;
    let (_, in_c, hw, ..) = module;
    let mut layers = vec![conv(&format!("inception_{m}/stem_1x1"), in_c, in_c, 1, 1, 0, hw)];
    let base = push_inception_layers(&mut layers, module);
    let schedule = vec![TopoOp::Conv(0), inception_branch(base)];
    Ok(Network::with_schedule(format!("inception_{m}"), layers, schedule))
}

/// All five networks of the evaluation, in the paper's order.
pub fn all() -> Vec<Network> {
    vec![alexnet(), googlenet(), vgg16(), vgg19(), nin()]
}

/// Look up by CLI name.
pub fn by_name(name: &str) -> crate::Result<Network> {
    match name {
        "alexnet" => Ok(alexnet()),
        "googlenet" => Ok(googlenet()),
        "vgg16" => Ok(vgg16()),
        "vgg19" => Ok(vgg19()),
        "nin" => Ok(nin()),
        other => Err(crate::Error::Config(format!(
            "unknown network `{other}` (want alexnet|googlenet|vgg16|vgg19|nin)"
        ))),
    }
}

/// The tiny CNN trained by `python/compile/aot.py` for the end-to-end
/// driver: 3 conv layers over 16×16 synthetic images with 2×2 stride-2
/// pools after conv1 and conv2. Must stay in sync with
/// `python/compile/model.py::TINY_CNN_SPEC`.
pub fn tiny_cnn() -> Network {
    Network::with_schedule(
        "tiny_cnn",
        vec![
            conv("conv1", 1, 8, 3, 1, 1, 16),
            conv("conv2", 8, 16, 3, 1, 1, 8),
            conv("conv3", 16, 16, 3, 1, 1, 4),
        ],
        vec![
            TopoOp::Conv(0),
            TopoOp::Pool(PoolSpec::max(2, 2, 0)), // 16 → 8
            TopoOp::Conv(1),
            TopoOp::Pool(PoolSpec::max(2, 2, 0)), // 8 → 4
            TopoOp::Conv(2),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_counts_match_architectures() {
        assert_eq!(alexnet().layers.len(), 5);
        assert_eq!(vgg16().layers.len(), 13);
        assert_eq!(vgg19().layers.len(), 16);
        assert_eq!(nin().layers.len(), 12);
        assert_eq!(googlenet().layers.len(), 3 + 9 * 6);
    }

    #[test]
    fn vgg16_macs_close_to_published() {
        // VGG-16 conv MACs ≈ 15.3 G (published figure for 224×224).
        let g = vgg16().total_macs() as f64 / 1e9;
        assert!((15.0..15.7).contains(&g), "VGG-16 GMACs = {g}");
    }

    #[test]
    fn alexnet_macs_close_to_published() {
        // AlexNet conv MACs ≈ 0.66 G (single-tower).
        let g = alexnet().total_macs() as f64 / 1e9;
        assert!((0.6..1.2).contains(&g), "AlexNet GMACs = {g}");
    }

    #[test]
    fn declared_fc_heads_match_published_shapes() {
        // VGG fc6–fc8: 25088→4096→4096→1000 ⇒ ≈123.6 M MACs.
        for net in [vgg16(), vgg19()] {
            let specs = net.fc_specs();
            assert_eq!(specs.len(), 3, "{}", net.name);
            assert_eq!(specs[0].in_features, 512 * 7 * 7);
            assert_eq!(specs[2].out_features, 1000);
            assert_eq!(net.fc_macs(), 123_633_664, "{}", net.name);
        }
        // GoogleNet loss3/classifier: 1024→1000.
        let g = googlenet();
        assert_eq!(g.fc_specs().len(), 1);
        assert_eq!(g.fc_macs(), 1_024_000);
        // Conv-only nets declare no head; conv accounting unchanged.
        assert_eq!(nin().fc_macs(), 0);
        assert_eq!(alexnet().fc_macs(), 0);
        assert_eq!(tiny_cnn().fc_macs(), 0);
    }

    #[test]
    fn scaled_zoo_heads_revalidate() {
        // `scaled` rewrites each head's in_features to the scaled
        // trunk's flattened output, so lowering keeps validating.
        let s = vgg16().scaled(16, 32);
        let specs = s.fc_specs();
        // 512/16 = 32 channels at 1×1 after five pools from 32².
        assert_eq!(specs[0].in_features, 32);
        assert_eq!(specs[1].in_features, specs[0].out_features);
    }

    #[test]
    fn googlenet_channels_chain() {
        // Each inception module's 3x3 path input must match its reduce.
        let net = googlenet();
        for m in ["3a", "3b", "4a", "4b", "4c", "4d", "4e", "5a", "5b"] {
            let reduce = net.layer(&format!("inception_{m}/3x3_reduce")).unwrap();
            let three = net.layer(&format!("inception_{m}/3x3")).unwrap();
            assert_eq!(reduce.out_c, three.in_c, "module {m}");
        }
    }

    /// The declared schedules reproduce each layer's recorded `in_hw`
    /// when propagated from the network's true input size — i.e. the
    /// schedule and the per-layer spatial bookkeeping agree exactly.
    #[test]
    fn declared_schedules_reproduce_recorded_spatial_sizes() {
        for net in all().into_iter().chain([tiny_cnn()]) {
            let first_hw = net.layers[0].in_hw;
            let re = net.scaled(1, first_hw);
            for (orig, prop) in net.layers.iter().zip(&re.layers) {
                assert_eq!(
                    orig.in_hw, prop.in_hw,
                    "{}: `{}` declares in_hw {} but its schedule delivers {}",
                    net.name, orig.name, orig.in_hw, prop.in_hw
                );
            }
        }
    }

    #[test]
    fn schedules_cover_every_layer_exactly_once() {
        fn count(ops: &[TopoOp], used: &mut [u32]) {
            for op in ops {
                match op {
                    TopoOp::Conv(i) => used[*i] += 1,
                    TopoOp::Branch(arms) => arms.iter().for_each(|a| count(a, used)),
                    _ => {}
                }
            }
        }
        for net in all().into_iter().chain([tiny_cnn()]) {
            let mut used = vec![0u32; net.layers.len()];
            count(&net.schedule, &mut used);
            for (l, n) in net.layers.iter().zip(&used) {
                assert_eq!(*n, 1, "{}: layer `{}` scheduled {} times", net.name, l.name, n);
            }
        }
    }

    #[test]
    fn vgg_spatial_sizes_halve() {
        let net = vgg16();
        assert_eq!(net.layer("conv1_1").unwrap().out_hw(), 224);
        assert_eq!(net.layer("conv5_3").unwrap().out_hw(), 14);
        // Five blocks ⇒ five declared pools.
        let pools = net
            .schedule
            .iter()
            .filter(|op| matches!(op, TopoOp::Pool(_)))
            .count();
        assert_eq!(pools, 5);
    }

    #[test]
    fn vgg16_block_extracts_chain() {
        let b3 = vgg16_block(3).unwrap();
        assert_eq!(b3.name, "vgg16_block3");
        assert_eq!(b3.layers.len(), 3);
        assert_eq!(b3.layers[0].in_c, 128);
        assert!(b3.layers.iter().all(|l| l.out_c == 256 && l.in_hw == 56));
        // Pool-free sequential schedule.
        assert_eq!(b3.schedule, vec![TopoOp::Conv(0), TopoOp::Conv(1), TopoOp::Conv(2)]);
        assert!(vgg16_block(6).is_err());
    }

    #[test]
    fn scaled_branch_concat_channels_stay_consistent() {
        // Divisor 3 divides none of the inception arm widths: the
        // floored arm sum (64/3 + 128/3 + 32/3 + 32/3 = 83) is less
        // than the floored original concat (256/3 = 85). `scaled`
        // propagates channels, so the consumers inherit the true sum
        // and the chain still lowers.
        let g = googlenet().scaled(3, 224);
        let arm_sum = 64 / 3 + 128 / 3 + 32 / 3 + 32 / 3;
        for name in [
            "inception_3b/1x1",
            "inception_3b/3x3_reduce",
            "inception_3b/5x5_reduce",
            "inception_3b/pool_proj",
        ] {
            assert_eq!(g.layer(name).unwrap().in_c, arm_sum, "{name}");
        }
        // Within-arm chaining propagates too: 3b's 3×3 consumes its
        // reduce's floored output.
        assert_eq!(
            g.layer("inception_3b/3x3").unwrap().in_c,
            g.layer("inception_3b/3x3_reduce").unwrap().out_c,
        );
    }

    #[test]
    fn inception_module_is_stem_plus_branch() {
        let m = inception_module("3a").unwrap();
        assert_eq!(m.layers.len(), 7);
        assert_eq!(m.layers[0].in_c, 192);
        assert_eq!(m.layers[0].out_c, 192);
        match &m.schedule[1] {
            TopoOp::Branch(arms) => assert_eq!(arms.len(), 4),
            other => panic!("expected a branch, got {other:?}"),
        }
        assert!(inception_module("9z").is_err());
    }

    #[test]
    fn by_name_roundtrip_and_errors() {
        for n in ["alexnet", "googlenet", "vgg16", "vgg19", "nin"] {
            assert_eq!(by_name(n).unwrap().name, n);
        }
        assert!(by_name("resnet50").is_err());
    }

    #[test]
    fn tiny_cnn_shapes_chain() {
        let t = tiny_cnn();
        assert_eq!(t.layers[0].out_hw(), 16);
        // conv2 input is 8 after the declared 2× pool.
        assert_eq!(t.layers[1].in_hw, 8);
        assert_eq!(t.layers[1].in_c, t.layers[0].out_c);
        assert_eq!(t.layers[2].in_c, t.layers[1].out_c);
    }
}
