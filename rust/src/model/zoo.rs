//! The five-network zoo of the paper's evaluation (§IV): AlexNet,
//! GoogleNet, VGG-16, VGG-19 and NiN — conv layers only, with the input
//! spatial sizes that follow each network's pooling schedule.
//!
//! Shapes follow the canonical Caffe Model Zoo prototxts the paper cites.

use super::layer::{ConvLayer, Network};

fn conv(name: &str, in_c: usize, out_c: usize, k: usize, stride: usize, pad: usize, in_hw: usize) -> ConvLayer {
    ConvLayer { name: name.to_string(), in_c, out_c, k, stride, pad, in_hw }
}

/// AlexNet (single-tower Caffe variant): 5 conv layers.
pub fn alexnet() -> Network {
    Network {
        name: "alexnet".into(),
        layers: vec![
            conv("conv1", 3, 96, 11, 4, 0, 227),
            conv("conv2", 96, 256, 5, 1, 2, 27),
            conv("conv3", 256, 384, 3, 1, 1, 13),
            conv("conv4", 384, 384, 3, 1, 1, 13),
            conv("conv5", 384, 256, 3, 1, 1, 13),
        ],
    }
}

/// VGG-16: 13 conv layers, all 3×3 pad 1.
pub fn vgg16() -> Network {
    let mut layers = Vec::new();
    // (block, convs, in_c, out_c, in_hw)
    let blocks = [
        (1, 2, 3, 64, 224),
        (2, 2, 64, 128, 112),
        (3, 3, 128, 256, 56),
        (4, 3, 256, 512, 28),
        (5, 3, 512, 512, 14),
    ];
    for (b, n, in_c, out_c, hw) in blocks {
        for i in 1..=n {
            let ic = if i == 1 { in_c } else { out_c };
            layers.push(conv(&format!("conv{b}_{i}"), ic, out_c, 3, 1, 1, hw));
        }
    }
    Network { name: "vgg16".into(), layers }
}

/// One pooling block of VGG-16 as a standalone chain network — the
/// plan executor's canonical non-tiny workload (`conv{b}_1..` layers,
/// all 3×3 stride-1 pad-1, same spatial size within the block).
pub fn vgg16_block(block: usize) -> crate::Result<Network> {
    let prefix = format!("conv{block}_");
    let layers: Vec<ConvLayer> = vgg16()
        .layers
        .into_iter()
        .filter(|l| l.name.starts_with(&prefix))
        .collect();
    if layers.is_empty() {
        return Err(crate::Error::Config(format!(
            "vgg16 has no block {block} (want 1..=5)"
        )));
    }
    Ok(Network { name: format!("vgg16_block{block}"), layers })
}

/// VGG-19: 16 conv layers (blocks 3–5 have four convs).
pub fn vgg19() -> Network {
    let mut layers = Vec::new();
    let blocks = [
        (1, 2, 3, 64, 224),
        (2, 2, 64, 128, 112),
        (3, 4, 128, 256, 56),
        (4, 4, 256, 512, 28),
        (5, 4, 512, 512, 14),
    ];
    for (b, n, in_c, out_c, hw) in blocks {
        for i in 1..=n {
            let ic = if i == 1 { in_c } else { out_c };
            layers.push(conv(&format!("conv{b}_{i}"), ic, out_c, 3, 1, 1, hw));
        }
    }
    Network { name: "vgg19".into(), layers }
}

/// Network-in-Network (ImageNet): 4 conv + 8 cccp (1×1 conv) layers.
pub fn nin() -> Network {
    Network {
        name: "nin".into(),
        layers: vec![
            conv("conv1", 3, 96, 11, 4, 0, 227),
            conv("cccp1", 96, 96, 1, 1, 0, 55),
            conv("cccp2", 96, 96, 1, 1, 0, 55),
            conv("conv2", 96, 256, 5, 1, 2, 27),
            conv("cccp3", 256, 256, 1, 1, 0, 27),
            conv("cccp4", 256, 256, 1, 1, 0, 27),
            conv("conv3", 256, 384, 3, 1, 1, 13),
            conv("cccp5", 384, 384, 1, 1, 0, 13),
            conv("cccp6", 384, 384, 1, 1, 0, 13),
            conv("conv4-1024", 384, 1024, 3, 1, 1, 6),
            conv("cccp7", 1024, 1024, 1, 1, 0, 6),
            conv("cccp8", 1024, 1000, 1, 1, 0, 6),
        ],
    }
}

/// GoogleNet (Inception v1): stem + 9 inception modules = 57 conv layers.
pub fn googlenet() -> Network {
    let mut layers = vec![
        conv("conv1/7x7_s2", 3, 64, 7, 2, 3, 224),
        conv("conv2/3x3_reduce", 64, 64, 1, 1, 0, 56),
        conv("conv2/3x3", 64, 192, 3, 1, 1, 56),
    ];
    // (name, in_c, hw, n1x1, n3x3r, n3x3, n5x5r, n5x5, pool_proj)
    let modules: [(&str, usize, usize, usize, usize, usize, usize, usize, usize); 9] = [
        ("3a", 192, 28, 64, 96, 128, 16, 32, 32),
        ("3b", 256, 28, 128, 128, 192, 32, 96, 64),
        ("4a", 480, 14, 192, 96, 208, 16, 48, 64),
        ("4b", 512, 14, 160, 112, 224, 24, 64, 64),
        ("4c", 512, 14, 128, 128, 256, 24, 64, 64),
        ("4d", 512, 14, 112, 144, 288, 32, 64, 64),
        ("4e", 528, 14, 256, 160, 320, 32, 128, 128),
        ("5a", 832, 7, 256, 160, 320, 32, 128, 128),
        ("5b", 832, 7, 384, 192, 384, 48, 128, 128),
    ];
    for (m, in_c, hw, n1, n3r, n3, n5r, n5, pp) in modules {
        layers.push(conv(&format!("inception_{m}/1x1"), in_c, n1, 1, 1, 0, hw));
        layers.push(conv(&format!("inception_{m}/3x3_reduce"), in_c, n3r, 1, 1, 0, hw));
        layers.push(conv(&format!("inception_{m}/3x3"), n3r, n3, 3, 1, 1, hw));
        layers.push(conv(&format!("inception_{m}/5x5_reduce"), in_c, n5r, 1, 1, 0, hw));
        layers.push(conv(&format!("inception_{m}/5x5"), n5r, n5, 5, 1, 2, hw));
        layers.push(conv(&format!("inception_{m}/pool_proj"), in_c, pp, 1, 1, 0, hw));
    }
    Network { name: "googlenet".into(), layers }
}

/// All five networks of the evaluation, in the paper's order.
pub fn all() -> Vec<Network> {
    vec![alexnet(), googlenet(), vgg16(), vgg19(), nin()]
}

/// Look up by CLI name.
pub fn by_name(name: &str) -> crate::Result<Network> {
    match name {
        "alexnet" => Ok(alexnet()),
        "googlenet" => Ok(googlenet()),
        "vgg16" => Ok(vgg16()),
        "vgg19" => Ok(vgg19()),
        "nin" => Ok(nin()),
        other => Err(crate::Error::Config(format!(
            "unknown network `{other}` (want alexnet|googlenet|vgg16|vgg19|nin)"
        ))),
    }
}

/// The tiny CNN trained by `python/compile/aot.py` for the end-to-end
/// driver: 3 conv layers over 16×16 synthetic images. Must stay in sync
/// with `python/compile/model.py::TINY_CNN_SPEC`.
pub fn tiny_cnn() -> Network {
    Network {
        name: "tiny_cnn".into(),
        layers: vec![
            conv("conv1", 1, 8, 3, 1, 1, 16),
            conv("conv2", 8, 16, 3, 1, 1, 8),
            conv("conv3", 16, 16, 3, 1, 1, 4),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_counts_match_architectures() {
        assert_eq!(alexnet().layers.len(), 5);
        assert_eq!(vgg16().layers.len(), 13);
        assert_eq!(vgg19().layers.len(), 16);
        assert_eq!(nin().layers.len(), 12);
        assert_eq!(googlenet().layers.len(), 3 + 9 * 6);
    }

    #[test]
    fn vgg16_macs_close_to_published() {
        // VGG-16 conv MACs ≈ 15.3 G (published figure for 224×224).
        let g = vgg16().total_macs() as f64 / 1e9;
        assert!((15.0..15.7).contains(&g), "VGG-16 GMACs = {g}");
    }

    #[test]
    fn alexnet_macs_close_to_published() {
        // AlexNet conv MACs ≈ 0.66 G (single-tower).
        let g = alexnet().total_macs() as f64 / 1e9;
        assert!((0.6..1.2).contains(&g), "AlexNet GMACs = {g}");
    }

    #[test]
    fn googlenet_channels_chain() {
        // Each inception module's 3x3 path input must match its reduce.
        let net = googlenet();
        for m in ["3a", "3b", "4a", "4b", "4c", "4d", "4e", "5a", "5b"] {
            let reduce = net.layer(&format!("inception_{m}/3x3_reduce")).unwrap();
            let three = net.layer(&format!("inception_{m}/3x3")).unwrap();
            assert_eq!(reduce.out_c, three.in_c, "module {m}");
        }
    }

    #[test]
    fn vgg_spatial_sizes_halve() {
        let net = vgg16();
        assert_eq!(net.layer("conv1_1").unwrap().out_hw(), 224);
        assert_eq!(net.layer("conv5_3").unwrap().out_hw(), 14);
    }

    #[test]
    fn vgg16_block_extracts_chain() {
        let b3 = vgg16_block(3).unwrap();
        assert_eq!(b3.name, "vgg16_block3");
        assert_eq!(b3.layers.len(), 3);
        assert_eq!(b3.layers[0].in_c, 128);
        assert!(b3.layers.iter().all(|l| l.out_c == 256 && l.in_hw == 56));
        assert!(vgg16_block(6).is_err());
    }

    #[test]
    fn by_name_roundtrip_and_errors() {
        for n in ["alexnet", "googlenet", "vgg16", "vgg19", "nin"] {
            assert_eq!(by_name(n).unwrap().name, n);
        }
        assert!(by_name("resnet50").is_err());
    }

    #[test]
    fn tiny_cnn_shapes_chain() {
        let t = tiny_cnn();
        assert_eq!(t.layers[0].out_hw(), 16);
        // conv2 input is 8 after 2× pooling recorded in in_hw.
        assert_eq!(t.layers[1].in_hw, 8);
        assert_eq!(t.layers[1].in_c, t.layers[0].out_c);
        assert_eq!(t.layers[2].in_c, t.layers[1].out_c);
    }
}
