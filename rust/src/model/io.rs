//! Weight-file I/O — the `TTW1` interchange format written by
//! `python/compile/aot.py` (JAX-trained, quantized weights) and read by
//! the rust side for end-to-end inference.
//!
//! Layout (little-endian):
//! ```text
//! magic   4 B   b"TTW1"
//! hdr_len u32   length of the JSON header in bytes
//! header  JSON  {"layers": [{"name", "shape": [o,i,kh,kw],
//!                            "frac_bits", "offset", "count"}, ...],
//!                "mode": "fp16"|"int8"}
//! data    i16[] concatenated per-layer weight payloads (raw quantized)
//! ```

use std::io::Read;
use std::path::Path;

use crate::config::Mode;
use crate::quant::QWeight;
use crate::util::json::{parse, Json};

/// One layer's loaded weights.
#[derive(Debug, Clone)]
pub struct LoadedLayer {
    pub name: String,
    /// OIHW shape.
    pub shape: [usize; 4],
    /// Fractional bits of the Q-format.
    pub frac_bits: u32,
    /// Quantized weights, row-major OIHW.
    pub weights: Vec<QWeight>,
}

/// A full weight file.
#[derive(Debug, Clone)]
pub struct LoadedWeights {
    pub mode: Mode,
    pub layers: Vec<LoadedLayer>,
}

impl LoadedWeights {
    pub fn layer(&self, name: &str) -> Option<&LoadedLayer> {
        self.layers.iter().find(|l| l.name == name)
    }

    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weights.len()).sum()
    }
}

/// Read a `TTW1` file.
pub fn read_weight_file(path: &Path) -> crate::Result<LoadedWeights> {
    let mut f = std::fs::File::open(path)?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"TTW1" {
        return Err(crate::Error::Artifact(format!(
            "{}: bad magic {:?} (want TTW1)",
            path.display(),
            magic
        )));
    }
    let mut len_bytes = [0u8; 4];
    f.read_exact(&mut len_bytes)?;
    let hdr_len = u32::from_le_bytes(len_bytes) as usize;
    let mut hdr = vec![0u8; hdr_len];
    f.read_exact(&mut hdr)?;
    let header = parse(
        std::str::from_utf8(&hdr)
            .map_err(|_| crate::Error::Artifact("header is not UTF-8".into()))?,
    )?;
    let mut data = Vec::new();
    f.read_to_end(&mut data)?;
    if data.len() % 2 != 0 {
        return Err(crate::Error::Artifact("odd payload length".into()));
    }
    let values: Vec<i16> = data
        .chunks_exact(2)
        .map(|c| i16::from_le_bytes([c[0], c[1]]))
        .collect();

    let mode: Mode = header
        .require("mode")?
        .as_str()
        .ok_or_else(|| crate::Error::Artifact("mode must be a string".into()))?
        .parse()
        .map_err(crate::Error::Artifact)?;

    let mut layers = Vec::new();
    for l in header
        .require("layers")?
        .as_arr()
        .ok_or_else(|| crate::Error::Artifact("layers must be an array".into()))?
    {
        let name = l.require("name")?.as_str().unwrap_or_default().to_string();
        let shape_v = l.require("shape")?;
        let dims = shape_v
            .as_arr()
            .ok_or_else(|| crate::Error::Artifact("shape must be an array".into()))?;
        if dims.len() != 4 {
            return Err(crate::Error::Artifact(format!("{name}: shape must be OIHW")));
        }
        let mut shape = [0usize; 4];
        for (i, d) in dims.iter().enumerate() {
            shape[i] = d
                .as_usize()
                .ok_or_else(|| crate::Error::Artifact(format!("{name}: bad shape dim")))?;
        }
        let offset = l.require("offset")?.as_usize().unwrap_or(0);
        let count = l.require("count")?.as_usize().unwrap_or(0);
        if shape.iter().product::<usize>() != count {
            return Err(crate::Error::Artifact(format!(
                "{name}: shape {:?} disagrees with count {count}",
                shape
            )));
        }
        if offset + count > values.len() {
            return Err(crate::Error::Artifact(format!(
                "{name}: payload overruns file ({} values total)",
                values.len()
            )));
        }
        let weights: Vec<QWeight> = values[offset..offset + count].iter().map(|&v| v as i32).collect();
        // Validate against the declared mode.
        for &w in &weights {
            if !crate::quant::fits_mode(w, mode) {
                return Err(crate::Error::Artifact(format!(
                    "{name}: weight {w} exceeds {mode} magnitude bound"
                )));
            }
        }
        let frac_bits = l.get("frac_bits").as_u64().unwrap_or(match mode {
            Mode::Fp16 => 15,
            Mode::Int8 => 7,
        }) as u32;
        layers.push(LoadedLayer { name, shape, frac_bits, weights });
    }
    Ok(LoadedWeights { mode, layers })
}

/// Write a `TTW1` file (used by tests and by rust-side weight dumping).
pub fn write_weight_file(path: &Path, w: &LoadedWeights) -> crate::Result<()> {
    use std::io::Write;
    let mut layer_objs = Vec::new();
    let mut payload: Vec<u8> = Vec::new();
    let mut offset = 0usize;
    for l in &w.layers {
        layer_objs.push(Json::obj([
            ("name", Json::Str(l.name.clone())),
            (
                "shape",
                Json::arr(l.shape.iter().map(|&d| Json::Num(d as f64))),
            ),
            ("frac_bits", Json::Num(l.frac_bits as f64)),
            ("offset", Json::Num(offset as f64)),
            ("count", Json::Num(l.weights.len() as f64)),
        ]));
        for &q in &l.weights {
            payload.extend_from_slice(&(q as i16).to_le_bytes());
        }
        offset += l.weights.len();
    }
    let header = Json::obj([
        ("mode", Json::Str(w.mode.to_string())),
        ("layers", Json::Arr(layer_objs)),
    ])
    .to_string_compact();
    let mut f = std::fs::File::create(path)?;
    f.write_all(b"TTW1")?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    f.write_all(&payload)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> LoadedWeights {
        LoadedWeights {
            mode: Mode::Fp16,
            layers: vec![
                LoadedLayer {
                    name: "conv1".into(),
                    shape: [2, 1, 3, 3],
                    frac_bits: 15,
                    weights: (0..18).map(|i| i * 100 - 900).collect(),
                },
                LoadedLayer {
                    name: "conv2".into(),
                    shape: [1, 2, 1, 1],
                    frac_bits: 15,
                    weights: vec![-32767, 32767],
                },
            ],
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("ttw_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        let w = sample();
        write_weight_file(&path, &w).unwrap();
        let r = read_weight_file(&path).unwrap();
        assert_eq!(r.mode, Mode::Fp16);
        assert_eq!(r.layers.len(), 2);
        assert_eq!(r.layer("conv1").unwrap().weights, w.layers[0].weights);
        assert_eq!(r.layer("conv2").unwrap().shape, [1, 2, 1, 1]);
        assert_eq!(r.total_weights(), 20);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join(format!("ttw_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_weight_file(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
