//! The explicit topology IR the zoo declares and the plan compiler
//! lowers.
//!
//! Earlier revisions stored *only* conv layers and tried to recover the
//! pooling schedule from spatial-size ratios between consecutive layers
//! (a 2× drop ⇒ 2×2 stride-2 pool), which could not express AlexNet's
//! and NiN's 3×3 stride-2 pools or GoogleNet's inception branching. A
//! [`Network`](super::Network) now carries an explicit op schedule:
//!
//! * [`TopoOp::Conv`] — one conv layer, referenced by index into
//!   `Network::layers` (shape metadata stays in [`ConvLayer`]).
//! * [`TopoOp::Pool`] — an inter-layer pool with explicit kind, kernel,
//!   stride and padding ([`PoolSpec`]); Caffe ceil-mode output sizing.
//! * [`TopoOp::Branch`] — inception-style parallel arms over one input,
//!   implicitly concatenated along the channel axis in arm order.
//! * [`TopoOp::GlobalAvgPool`] / [`TopoOp::Fc`] — the classifier head
//!   (NiN ends in a global average pool with no FC; chains whose weight
//!   file carries an `fc` layer get the head appended at lowering).
//!   `Fc` carries an [`FcSpec`] naming the weight layer and its
//!   reduction shape, so the published FC heads (VGG fc6–8,
//!   GoogleNet's loss3/classifier) are declared topology the MAC
//!   accounting and simulators can see even though only the single
//!   `fc` head is executable.
//!
//! The IR is *declared* topology only — validation (shape chaining,
//! weight availability, one use per layer) happens when
//! `plan::graph::derive_graph` lowers it into an execution plan.
//!
//! [`ConvLayer`]: super::ConvLayer

/// Pooling operator kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    /// Max over the window's in-bounds taps (padding never wins).
    Max,
    /// Floor-divided mean over the window's in-bounds taps
    /// (padding excluded from the count).
    Avg,
}

/// One pooling stage: kind + square kernel, stride, zero padding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolSpec {
    pub kind: PoolKind,
    /// Kernel height/width (square windows throughout the zoo).
    pub k: usize,
    pub stride: usize,
    /// Padding on each side. Must be `< k` so no window lies entirely
    /// in the padding.
    pub pad: usize,
}

impl PoolSpec {
    /// Max pool of the given geometry.
    pub fn max(k: usize, stride: usize, pad: usize) -> Self {
        Self { kind: PoolKind::Max, k, stride, pad }
    }

    /// Average pool of the given geometry.
    pub fn avg(k: usize, stride: usize, pad: usize) -> Self {
        Self { kind: PoolKind::Avg, k, stride, pad }
    }

    /// Output spatial size under Caffe's ceil-mode convention: the last
    /// window may hang off the padded edge (it gets clipped to the
    /// in-bounds taps), but every window must *start* inside
    /// `input + pad`. This reproduces the published schedules exactly —
    /// e.g. GoogleNet's 3×3 stride-2 pool maps 56 → 28 (ceil), while
    /// AlexNet's maps 55 → 27 and VGG's 2×2 stride-2 maps 224 → 112
    /// (both exact).
    pub fn out_hw(&self, in_hw: usize) -> crate::Result<usize> {
        if self.k == 0 || self.stride == 0 {
            return Err(crate::Error::Config(format!(
                "pool kernel/stride must be non-zero (k={}, stride={})",
                self.k, self.stride
            )));
        }
        if self.pad >= self.k {
            return Err(crate::Error::Config(format!(
                "pool pad {} must be smaller than kernel {}",
                self.pad, self.k
            )));
        }
        let padded = in_hw + 2 * self.pad;
        if padded < self.k {
            return Err(crate::Error::Shape(format!(
                "{in_hw}×{in_hw} input (pad {}) smaller than {}×{} pool window",
                self.pad, self.k, self.k
            )));
        }
        // ceil((padded - k) / stride) + 1 …
        let mut out = (padded - self.k).div_ceil(self.stride) + 1;
        // … clipped so the last window starts inside input + pad.
        if (out - 1) * self.stride >= in_hw + self.pad {
            out -= 1;
        }
        Ok(out)
    }
}

/// One declared fully-connected classifier layer: name + reduction
/// shape. The zoo declares the published FC heads (VGG's fc6–fc8,
/// GoogleNet's loss3/classifier) so MAC/weight accounting and the
/// simulators can cover them (`Network::fc_macs`,
/// `tetris simulate --include-fc`); lowering validates that
/// `in_features` matches what the trunk delivers (flattened
/// `C·H·W`, or `C` after a `GlobalAvgPool`/previous `Fc`).
///
/// Execution: when the weight set carries a layer for **every** head
/// of the stack, each compiles into per-name FC lanes and the plan
/// runs image → logits (a spatial trunk flattens first; every head
/// but the last is activation-fused). A stack with no weighted head
/// is declaration-only — the executor stops at the conv trunk,
/// exactly as before it was declared — and a mixed stack is rejected
/// at lowering.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FcSpec {
    /// Weight-layer name, e.g. `fc6` or `loss3/classifier`.
    pub name: String,
    /// Input features (the flattened trunk: `C·H·W`).
    pub in_features: usize,
    /// Output features (next FC's input, or the class count).
    pub out_features: usize,
}

impl FcSpec {
    pub fn new(name: impl Into<String>, in_features: usize, out_features: usize) -> Self {
        Self { name: name.into(), in_features, out_features }
    }

    /// Weights in this layer (= MACs per image: every weight is used
    /// exactly once).
    pub fn weight_count(&self) -> u64 {
        (self.in_features * self.out_features) as u64
    }

    /// Multiply-accumulates for one input image.
    pub fn macs(&self) -> u64 {
        self.weight_count()
    }
}

/// One node of a declared network schedule. See the module docs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TopoOp {
    /// Convolution of `Network::layers[i]` (ReLU + requantization are
    /// implicit — every conv in the zoo is activation-fused).
    Conv(usize),
    Pool(PoolSpec),
    /// Parallel arms over one input, concatenated along channels in arm
    /// order. Arms may not contain `GlobalAvgPool`/`Fc`.
    Branch(Vec<Vec<TopoOp>>),
    /// Global average pool: i64 sum then floor division, collapsing
    /// (N, C, H, W) → (N, C).
    GlobalAvgPool,
    /// Fully connected classifier layer (see [`FcSpec`]). Only valid
    /// at the schedule tail: after the last conv/pool stage, with
    /// nothing but further `Fc` entries following.
    Fc(FcSpec),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_mode_matches_published_schedules() {
        let p3s2 = PoolSpec::max(3, 2, 0);
        // AlexNet: 55 → 27 → 13 → 6 (exact divisions).
        assert_eq!(p3s2.out_hw(55).unwrap(), 27);
        assert_eq!(p3s2.out_hw(27).unwrap(), 13);
        assert_eq!(p3s2.out_hw(13).unwrap(), 6);
        // GoogleNet: 112 → 56, 56 → 28, 28 → 14, 14 → 7 (ceil mode).
        assert_eq!(p3s2.out_hw(112).unwrap(), 56);
        assert_eq!(p3s2.out_hw(56).unwrap(), 28);
        assert_eq!(p3s2.out_hw(28).unwrap(), 14);
        assert_eq!(p3s2.out_hw(14).unwrap(), 7);
        // VGG / tiny CNN: 2×2 stride-2 halves even extents exactly.
        let p2s2 = PoolSpec::max(2, 2, 0);
        assert_eq!(p2s2.out_hw(224).unwrap(), 112);
        assert_eq!(p2s2.out_hw(16).unwrap(), 8);
    }

    #[test]
    fn clip_keeps_windows_starting_inside() {
        // 13 with k=3 s=2: naive ceil((13-3)/2)+1 = 6 and the window at
        // oy=5 starts at 10 < 13 — no clip needed, stays 6 (a start-
        // inside-only rule would wrongly allow a 7th window at 12).
        assert_eq!(PoolSpec::max(3, 2, 0).out_hw(13).unwrap(), 6);
        // Same-size pool: 3×3 stride-1 pad-1 preserves any extent
        // (the inception pool-proj arm's geometry).
        let same = PoolSpec::max(3, 1, 1);
        for hw in [2usize, 7, 14, 28] {
            assert_eq!(same.out_hw(hw).unwrap(), hw);
        }
    }

    #[test]
    fn fc_spec_counts_weights_as_macs() {
        let fc6 = FcSpec::new("fc6", 512 * 7 * 7, 4096);
        assert_eq!(fc6.weight_count(), 25_088 * 4096);
        assert_eq!(fc6.macs(), fc6.weight_count());
        assert_eq!(fc6.name, "fc6");
    }

    #[test]
    fn degenerate_pools_rejected() {
        assert!(PoolSpec::max(3, 2, 0).out_hw(2).is_err()); // window > input
        assert!(PoolSpec::max(0, 2, 0).out_hw(8).is_err()); // k = 0
        assert!(PoolSpec { kind: PoolKind::Max, k: 2, stride: 0, pad: 0 }
            .out_hw(8)
            .is_err()); // stride = 0
        assert!(PoolSpec::max(2, 2, 2).out_hw(8).is_err()); // pad ≥ k
    }
}
