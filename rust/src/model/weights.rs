//! Weight sources: calibrated synthetic generators and trained-weight
//! files.
//!
//! The paper's kneading/SAC results depend only on the *bit-level
//! statistics* of the quantized weights (zero-value fraction, per-bit
//! essential density — Table 1 / Figure 2), not on classification
//! semantics. Since the Caffe Model Zoo checkpoints are unavailable
//! offline, [`BitProfile`] generates weight populations whose statistics
//! are calibrated to the paper's published measurements per network.
//! [`laplacian`] generates value-realistic weights (trained conv weights
//! are empirically Laplace-distributed), used for cross-checks, and real
//! trained weights flow in from `artifacts/weights.bin` via `model::io`.
//!
//! NOTE on the paper's internal inconsistency: Table 1 reports 68.9%
//! zero bits (⇒ ~31% essential density) while Figure 2's prose claims
//! 50–60% essential density per position. Both cannot hold; we calibrate
//! to Table 1 (the quantitative anchor for kneading gains) and keep
//! Figure 2's *shape* (near-uniform density with a cliff at bits 3–5).
//! See EXPERIMENTS.md.

use crate::config::Mode;
use crate::quant::{quantize_q, QFormat, QWeight};
use crate::util::rng::Rng;

/// Per-bit essential-density profile for one (network, mode) pair.
#[derive(Debug, Clone, PartialEq)]
pub struct BitProfile {
    /// Network this profile models.
    pub network: &'static str,
    /// Probability a weight is exactly zero (Table 1 column 1).
    pub zero_weight_frac: f64,
    /// Essential-bit probability at each bit position, LSB first.
    /// Length = mode weight bits.
    pub density: Vec<f64>,
}

impl BitProfile {
    /// Build a profile from the Table 1 anchors: overall zero-bit
    /// fraction + the Figure 2 cliff at bits 3–5.
    ///
    /// The MSB position never carries an essential bit (sign-magnitude:
    /// bit `B-1` is the sign's slot, magnitudes keep one headroom bit).
    /// The remaining per-position density is near-uniform with a mild
    /// downward slope toward the MSB (small-magnitude weights), and bits
    /// 3–5 pinned to <1% ("the cliff", Fig 2 observation (2)).
    pub fn from_anchors(
        network: &'static str,
        zero_weight_frac: f64,
        zero_bit_frac: f64,
        mode: Mode,
    ) -> Self {
        let bits = mode.weight_bits();
        let msb = bits - 1;
        let mean_density = 1.0 - zero_bit_frac;
        let cliff: &[usize] = if bits == 16 { &[3, 4, 5] } else { &[3] };
        let cliff_density = 0.005;
        let active: Vec<usize> =
            (0..bits).filter(|b| !cliff.contains(b) && *b != msb).collect();
        // Solve for the active-position base density preserving the mean:
        //   mean*bits = cliff_density*|cliff| + 0·msb + base_total
        let base_total = mean_density * bits as f64 - cliff_density * cliff.len() as f64;
        let base = base_total / active.len() as f64;
        // Mild slope: +20% at LSB tapering to -20% near the MSB.
        let mut density = vec![0.0; bits];
        for (idx, &b) in active.iter().enumerate() {
            let t = idx as f64 / (active.len() - 1).max(1) as f64;
            density[b] = base * (1.2 - 0.4 * t);
        }
        for &b in cliff {
            density[b] = cliff_density;
        }
        // Renormalize active positions to restore the exact mean.
        let cur: f64 = active.iter().map(|&b| density[b]).sum();
        let fix = base_total / cur;
        for &b in &active {
            density[b] = (density[b] * fix).clamp(0.0, 0.98);
        }
        Self { network, zero_weight_frac, density }
    }

    /// Number of bit positions this profile covers.
    pub fn bits(&self) -> usize {
        self.density.len()
    }

    /// Expected zero-bit fraction of generated weights (sanity check —
    /// should match the Table 1 anchor up to the zero-weight correction).
    pub fn expected_zero_bit_frac(&self) -> f64 {
        let mean: f64 = self.density.iter().sum::<f64>() / self.bits() as f64;
        // Zero-valued weights contribute all-zero bits.
        1.0 - mean * (1.0 - self.zero_weight_frac)
    }

    /// Draw one weight: bits sampled independently per position, sign
    /// uniform. Zero weights injected at `zero_weight_frac`.
    pub fn sample(&self, rng: &mut Rng) -> QWeight {
        if rng.chance(self.zero_weight_frac) {
            return 0;
        }
        let mut mag: u32 = 0;
        for (b, &d) in self.density.iter().enumerate() {
            if rng.chance(d) {
                mag |= 1 << b;
            }
        }
        if mag == 0 {
            // Conditioned on non-zero: give it one essential bit at a
            // non-cliff position (keeps zero_weight_frac exact).
            mag = 1 << (rng.below(3) as u32); // bits 0..2 are non-cliff
        }
        debug_assert!(mag < 1 << (self.bits() - 1), "MSB density must be 0");
        let sign = if rng.chance(0.5) { -1 } else { 1 };
        sign * mag as i32
    }

    /// Generate `n` weights.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<QWeight> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

/// Table 1 of the paper: (network, zero-weight %, zero-bit % of fp16
/// weights).
pub const TABLE1_ANCHORS: [(&str, f64, f64); 5] = [
    ("alexnet", 0.093e-2, 70.52e-2),
    ("googlenet", 0.050e-2, 65.23e-2),
    ("vgg16", 0.156e-2, 70.52e-2),
    ("vgg19", 0.182e-2, 71.09e-2),
    ("nin", 0.193e-2, 67.02e-2),
];

/// int8 anchors under the Table 1 calibration: requantizing to 8 bits
/// concentrates essential bits, so the zero-bit fraction drops.
pub const INT8_ZERO_BIT_FRAC: f64 = 0.52;

/// Which of the paper's two mutually inconsistent bit-statistics claims
/// to calibrate the generator against (see module docs + EXPERIMENTS.md):
///
/// * [`Table1`](DensityCalibration::Table1) — 68.9% zero bits ⇒ ~31%
///   essential density. Reproduces the paper's Table 1 exactly; kneads
///   *harder* than the paper's own speedups (Fig 8/11) imply.
/// * [`Fig2`](DensityCalibration::Fig2) — 50–60% essential density per
///   position. Reproduces Fig 11's T_ks/T_base curve (0.75 @ KS=10 →
///   0.64 @ KS=32 for AlexNet) and therefore the Fig 8 speedups. The
///   performance figures default to this calibration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DensityCalibration {
    Table1,
    Fig2,
}

/// Fig 2-calibration essential densities (fp16) per network. 0.50 makes
/// E[max_b Binom(KS, d)] reproduce Fig 11's AlexNet curve; small per-
/// network offsets give Fig 8's spread across models.
const FIG2_FP16_DENSITY: [(&str, f64); 6] = [
    ("alexnet", 0.50),
    ("googlenet", 0.57),
    ("vgg16", 0.55),
    ("vgg19", 0.55),
    ("nin", 0.53),
    ("tiny_cnn", 0.54),
];

/// Fig 2-calibration int8 density: the paper's Fig 11 int8 curve is
/// nearly flat at T_ks/T_base ≈ 0.49 (relative to the fp16 unkneaded
/// base), i.e. kneading adds only ~2% on top of the 2× mode throughput —
/// implying near-saturated essential density after 8-bit requantization.
const FIG2_INT8_DENSITY: f64 = 0.93;

/// Profile for a (network, mode) pair calibrated to the paper's Table 1
/// (bit-statistics experiments).
pub fn profile_for(network: &str, mode: Mode) -> crate::Result<BitProfile> {
    profile_with(network, mode, DensityCalibration::Table1)
}

/// Profile under an explicit density calibration.
pub fn profile_with(
    network: &str,
    mode: Mode,
    calib: DensityCalibration,
) -> crate::Result<BitProfile> {
    let (name, zw, zb_fp16) = TABLE1_ANCHORS
        .iter()
        .find(|(n, _, _)| *n == network)
        .copied()
        .or(if network == "tiny_cnn" {
            // The tiny CNN's real weights replace this profile at run
            // time; the synthetic fallback uses the geo-mean anchors.
            Some(("tiny_cnn", 0.135e-2, 68.88e-2))
        } else {
            None
        })
        .ok_or_else(|| crate::Error::Config(format!("no bit profile for `{network}`")))?;
    match calib {
        DensityCalibration::Table1 => {
            let zb = match mode {
                Mode::Fp16 => zb_fp16,
                Mode::Int8 => INT8_ZERO_BIT_FRAC,
            };
            Ok(BitProfile::from_anchors(name, zw, zb, mode))
        }
        DensityCalibration::Fig2 => {
            let density = match mode {
                Mode::Fp16 => {
                    FIG2_FP16_DENSITY
                        .iter()
                        .find(|(n, _)| *n == name)
                        .map(|(_, d)| *d)
                        .unwrap_or(0.50)
                }
                Mode::Int8 => FIG2_INT8_DENSITY,
            };
            // Mean density = d over active (non-cliff, non-MSB) bits
            // ⇒ zero-bit fraction handed to from_anchors.
            let bits = mode.weight_bits() as f64;
            let cliff_n = if mode == Mode::Fp16 { 3.0 } else { 1.0 };
            let active = bits - cliff_n - 1.0;
            let zb = 1.0 - (density * active + 0.005 * cliff_n) / bits;
            Ok(BitProfile::from_anchors(name, zw, zb, mode))
        }
    }
}

/// Synthetic [`LoadedWeights`](super::LoadedWeights) for an arbitrary
/// chain network, drawn from the bit profile of `profile_name` (one of
/// the Table 1 networks or `tiny_cnn`). Every layer gets `frac_bits`;
/// generation is deterministic in `seed`. Conv-only — append an `fc`
/// layer yourself for classifier heads (see
/// `coordinator::SacBackend::synthetic_weights`).
pub fn synthetic_loaded(
    net: &super::Network,
    mode: Mode,
    frac_bits: u32,
    profile_name: &str,
    calib: DensityCalibration,
    seed: u64,
) -> crate::Result<super::LoadedWeights> {
    let profile = profile_with(profile_name, mode, calib)?;
    let mut rng = Rng::new(seed);
    let layers = net
        .layers
        .iter()
        .map(|l| super::LoadedLayer {
            name: l.name.clone(),
            shape: [l.out_c, l.in_c, l.k, l.k],
            frac_bits,
            weights: profile.generate(l.weight_count() as usize, &mut rng),
        })
        .collect();
    Ok(super::LoadedWeights { mode, layers })
}

/// [`synthetic_loaded`] plus one weight layer per **declared FC head**
/// (`Network::fc_specs`), shaped `[out_features, in_features, 1, 1]` —
/// the set that makes a zoo network with a published classifier stack
/// (VGG fc6–8, GoogleNet loss3/classifier) compile into an executable
/// image → logits plan. Conv layers draw the exact same weights as
/// [`synthetic_loaded`] under the same seed (heads draw from a forked
/// stream), so trunk-only results stay comparable across both sets.
pub fn synthetic_loaded_with_heads(
    net: &super::Network,
    mode: Mode,
    frac_bits: u32,
    profile_name: &str,
    calib: DensityCalibration,
    seed: u64,
) -> crate::Result<super::LoadedWeights> {
    let mut loaded = synthetic_loaded(net, mode, frac_bits, profile_name, calib, seed)?;
    let profile = profile_with(profile_name, mode, calib)?;
    let mut rng = Rng::new(seed ^ 0xFC_4EAD);
    for spec in net.fc_specs() {
        loaded.layers.push(super::LoadedLayer {
            name: spec.name.clone(),
            shape: [spec.out_features, spec.in_features, 1, 1],
            frac_bits,
            weights: profile.generate(spec.weight_count() as usize, &mut rng),
        });
    }
    Ok(loaded)
}

/// Value-realistic generator: Laplace(0, b) quantized to the mode's
/// Q-format. Trained conv weights are empirically Laplacian with
/// scale ≈ 0.03–0.06 of the weight range.
pub fn laplacian(n: usize, scale: f64, mode: Mode, rng: &mut Rng) -> Vec<QWeight> {
    let fmt = QFormat::for_mode(mode);
    (0..n).map(|_| quantize_q(rng.laplace(scale) as f32, fmt)).collect()
}

/// Activations: post-ReLU feature-map values. Empirically ~half are
/// exactly zero and the rest follow a truncated exponential-ish tail; we
/// model Bernoulli(1-sparsity) × Exp quantized to Q8.8.
pub fn activations(n: usize, sparsity: f64, rng: &mut Rng) -> Vec<crate::quant::QAct> {
    (0..n)
        .map(|_| {
            if rng.chance(sparsity) {
                0
            } else {
                // Exponential tail, mean 0.25, clipped to [0, 8).
                let v = (-rng.f64().max(1e-12).ln() * 0.25).min(7.99);
                (v * 256.0) as i32
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::stats::BitStats;

    #[test]
    fn profile_reproduces_table1_anchors() {
        let mut rng = Rng::new(42);
        for (name, zw, zb) in TABLE1_ANCHORS {
            let p = profile_for(name, Mode::Fp16).unwrap();
            let ws = p.generate(200_000, &mut rng);
            let mut s = BitStats::new(Mode::Fp16);
            s.add_all(&ws);
            assert!(
                (s.zero_weight_fraction() - zw).abs() < 0.0015,
                "{name}: zero-weight {} vs anchor {zw}",
                s.zero_weight_fraction()
            );
            assert!(
                (s.zero_bit_fraction() - zb).abs() < 0.02,
                "{name}: zero-bit {} vs anchor {zb}",
                s.zero_bit_fraction()
            );
        }
    }

    #[test]
    fn profile_has_fig2_cliff() {
        let mut rng = Rng::new(7);
        let p = profile_for("vgg16", Mode::Fp16).unwrap();
        let ws = p.generate(100_000, &mut rng);
        let mut s = BitStats::new(Mode::Fp16);
        s.add_all(&ws);
        let d = s.essential_density_per_bit();
        for b in [3, 4, 5] {
            assert!(d[b] < 0.01, "bit {b} density {} not a cliff", d[b]);
        }
        // Non-cliff positions stay well above the cliff.
        assert!(d[0] > 0.2 && d[8] > 0.2);
    }

    #[test]
    fn generated_weights_fit_mode() {
        let mut rng = Rng::new(3);
        for mode in [Mode::Fp16, Mode::Int8] {
            let p = profile_for("alexnet", mode).unwrap();
            for w in p.generate(10_000, &mut rng) {
                assert!(crate::quant::fits_mode(w, mode), "weight {w:#x} overflows {mode}");
            }
        }
    }

    #[test]
    fn laplacian_quantizes_to_small_values() {
        let mut rng = Rng::new(11);
        let ws = laplacian(50_000, 0.04, Mode::Fp16, &mut rng);
        let mut s = BitStats::new(Mode::Fp16);
        s.add_all(&ws);
        // Laplace(0.04) in Q1.15: mean |w| ≈ 0.04*32768 ≈ 1311 → high
        // bits mostly zero → zero-bit fraction well above 60%.
        assert!(s.zero_bit_fraction() > 0.6, "zero-bit {}", s.zero_bit_fraction());
        assert!(ws.iter().any(|&w| w < 0) && ws.iter().any(|&w| w > 0));
    }

    #[test]
    fn activations_respect_sparsity() {
        let mut rng = Rng::new(5);
        let acts = activations(100_000, 0.5, &mut rng);
        let zeros = acts.iter().filter(|&&a| a == 0).count() as f64 / 1e5;
        assert!((zeros - 0.5).abs() < 0.02, "sparsity {zeros}");
        assert!(acts.iter().all(|&a| (0..1 << 15).contains(&a)));
    }

    #[test]
    fn unknown_network_is_error() {
        assert!(profile_for("resnet", Mode::Fp16).is_err());
    }

    #[test]
    fn synthetic_heads_extend_the_conv_set_without_disturbing_it() {
        let net = crate::model::zoo::vgg16().scaled(16, 32);
        let plain =
            synthetic_loaded(&net, Mode::Fp16, 10, "vgg16", DensityCalibration::Fig2, 9)
                .unwrap();
        let with = synthetic_loaded_with_heads(
            &net,
            Mode::Fp16,
            10,
            "vgg16",
            DensityCalibration::Fig2,
            9,
        )
        .unwrap();
        // Conv layers identical; one extra layer per declared head.
        assert_eq!(with.layers.len(), plain.layers.len() + 3);
        for (a, b) in plain.layers.iter().zip(&with.layers) {
            assert_eq!(a.weights, b.weights, "{}", a.name);
        }
        for (spec, wl) in net.fc_specs().iter().zip(&with.layers[plain.layers.len()..]) {
            assert_eq!(wl.name, spec.name);
            assert_eq!(wl.shape, [spec.out_features, spec.in_features, 1, 1]);
            assert_eq!(wl.weights.len() as u64, spec.weight_count());
        }
        // Conv-only networks get no extra layers.
        let nin = crate::model::zoo::nin().scaled(16, 64);
        let nw = synthetic_loaded_with_heads(
            &nin,
            Mode::Fp16,
            10,
            "nin",
            DensityCalibration::Fig2,
            9,
        )
        .unwrap();
        assert_eq!(nw.layers.len(), nin.layers.len());
    }

    #[test]
    fn synthetic_loaded_matches_topology_and_is_deterministic() {
        let net = crate::model::zoo::tiny_cnn();
        let a = synthetic_loaded(&net, Mode::Fp16, 12, "tiny_cnn", DensityCalibration::Fig2, 7)
            .unwrap();
        let b = synthetic_loaded(&net, Mode::Fp16, 12, "tiny_cnn", DensityCalibration::Fig2, 7)
            .unwrap();
        assert_eq!(a.layers.len(), net.layers.len());
        for (wl, l) in a.layers.iter().zip(&net.layers) {
            assert_eq!(wl.shape, [l.out_c, l.in_c, l.k, l.k]);
            assert_eq!(wl.frac_bits, 12);
            assert_eq!(wl.weights.len() as u64, l.weight_count());
        }
        for (wa, wb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(wa.weights, wb.weights);
        }
        assert!(synthetic_loaded(&net, Mode::Fp16, 12, "nope", DensityCalibration::Fig2, 7)
            .is_err());
    }
}
