//! Chip area composition — reproduces Table 2.

use crate::config::{AccelConfig, CalibConfig};

/// Area report for one design: total plus per-component breakdown.
#[derive(Debug, Clone)]
pub struct AreaReport {
    pub design: &'static str,
    /// (component name, mm² for the whole chip).
    pub components: Vec<(&'static str, f64)>,
}

impl AreaReport {
    pub fn total_mm2(&self) -> f64 {
        self.components.iter().map(|(_, a)| a).sum()
    }

    /// Per-PE breakdown (Table 2's right half).
    pub fn per_pe(&self, pes: usize) -> Vec<(&'static str, f64)> {
        self.components.iter().map(|&(n, a)| (n, a / pes as f64)).collect()
    }
}

/// Compose the chip area of a design from the component table.
pub fn chip_area(design: &str, cfg: &AccelConfig, calib: &CalibConfig) -> crate::Result<AreaReport> {
    let a = &calib.area;
    let pes = cfg.pes as f64;
    let lanes = cfg.splitters_per_pe as f64;
    let report = match design {
        "tetris" => AreaReport {
            design: "tetris",
            components: vec![
                ("I/O RAMs", a.io_rams_mm2 * pes),
                ("Throttle Buffer", a.throttle_mm2 * pes),
                ("Splitter Array", a.splitter_array_mm2 * pes),
                ("Activation Function", a.act_fn_mm2 * pes),
                ("Segment Adders", a.segment_adders_mm2 * pes),
                ("Rear Adder Tree", a.adder_tree_mm2 * pes),
            ],
        },
        "dadn" => AreaReport {
            design: "dadn",
            components: vec![
                ("I/O RAMs", a.io_rams_mm2 * pes),
                ("Activation Function", a.act_fn_mm2 * pes),
                ("Multiplier Lanes", a.mult_lane_mm2 * lanes * pes),
            ],
        },
        "pra" => AreaReport {
            design: "pra",
            components: vec![
                ("I/O RAMs", a.io_rams_mm2 * pes),
                ("Activation Function", a.act_fn_mm2 * pes),
                ("Bit-serial Lanes", a.pra_lane_mm2 * lanes * pes),
                ("Weight FIFOs (16x)", a.pra_fifo_mm2 * pes),
            ],
        },
        other => {
            return Err(crate::Error::Config(format!("unknown design `{other}` for area model")))
        }
    };
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn defaults() -> (AccelConfig, CalibConfig) {
        (AccelConfig::default(), CalibConfig::default())
    }

    /// Table 2 anchors: DaDN 79.36, PRA 153.65, Tetris 89.76 mm².
    #[test]
    fn totals_match_table2() {
        let (cfg, calib) = defaults();
        let t = chip_area("tetris", &cfg, &calib).unwrap().total_mm2();
        let d = chip_area("dadn", &cfg, &calib).unwrap().total_mm2();
        let p = chip_area("pra", &cfg, &calib).unwrap().total_mm2();
        assert!((t - 89.76).abs() < 0.5, "tetris {t}");
        assert!((d - 79.36).abs() < 0.5, "dadn {d}");
        assert!((p - 153.65).abs() < 1.0, "pra {p}");
        // Overheads over DaDN: 1.13× and 1.93×.
        assert!(((t / d) - 1.131).abs() < 0.02);
        assert!(((p / d) - 1.936).abs() < 0.05);
    }

    #[test]
    fn tetris_breakdown_percentages() {
        let (cfg, calib) = defaults();
        let rep = chip_area("tetris", &cfg, &calib).unwrap();
        let total = rep.total_mm2();
        let pct = |name: &str| {
            rep.components.iter().find(|(n, _)| *n == name).unwrap().1 / total * 100.0
        };
        // Table 2: I/O RAMs 68.24%, Throttle 17.06%, Splitters 9.70%.
        assert!((pct("I/O RAMs") - 68.24).abs() < 1.0);
        assert!((pct("Throttle Buffer") - 17.06).abs() < 0.5);
        assert!((pct("Splitter Array") - 9.70).abs() < 0.5);
    }

    #[test]
    fn unknown_design_errors() {
        let (cfg, calib) = defaults();
        assert!(chip_area("eyeriss", &cfg, &calib).is_err());
    }
}
