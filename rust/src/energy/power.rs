//! Dynamic + leakage energy from simulator activity counts.

use crate::config::{CalibConfig, Mode};
use crate::sim::{ChipActivity, NetworkSim};

/// Energy breakdown for a simulated workload, in joules.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub mults_j: f64,
    pub adds_j: f64,
    pub splitters_j: f64,
    pub shifters_j: f64,
    pub memory_j: f64,
    pub fifo_j: f64,
    pub regs_j: f64,
    pub leakage_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.mults_j
            + self.adds_j
            + self.splitters_j
            + self.shifters_j
            + self.memory_j
            + self.fifo_j
            + self.regs_j
            + self.leakage_j
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.mults_j += o.mults_j;
        self.adds_j += o.adds_j;
        self.splitters_j += o.splitters_j;
        self.shifters_j += o.shifters_j;
        self.memory_j += o.memory_j;
        self.fifo_j += o.fifo_j;
        self.regs_j += o.regs_j;
        self.leakage_j += o.leakage_j;
    }
}

const PJ: f64 = 1e-12;

/// Energy of one layer's activity over `cycles` at the given mode.
pub fn layer_energy(
    activity: &ChipActivity,
    cycles: u64,
    mode: Mode,
    pes: usize,
    calib: &CalibConfig,
) -> EnergyBreakdown {
    let e = &calib.energy;
    let add_pj = match mode {
        Mode::Fp16 => e.add16_pj,
        Mode::Int8 => e.add8_pj,
    };
    EnergyBreakdown {
        mults_j: activity.mults * e.mult16_pj * PJ,
        adds_j: (activity.adds + activity.tree_drains * 15.0) * add_pj * PJ,
        splitters_j: activity.splitter_decodes * e.splitter_pj * PJ,
        shifters_j: activity.shifts * e.shifter_pj * PJ,
        memory_j: (activity.sram_reads * e.sram_read_pj + activity.edram_reads * e.edram_read_pj)
            * PJ,
        fifo_j: activity.fifo_ops * e.fifo_pj * PJ,
        regs_j: activity.reg_writes * e.reg_write_pj * PJ,
        leakage_j: cycles as f64 * pes as f64 * e.leakage_pe_pj * PJ,
    }
}

/// Whole-network energy from a [`NetworkSim`].
pub fn network_energy(sim: &NetworkSim, calib: &CalibConfig) -> EnergyBreakdown {
    let mut total = EnergyBreakdown::default();
    for l in &sim.per_layer {
        total.add(&layer_energy(&l.activity, l.cycles, sim.config.mode, sim.config.pes, calib));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AccelConfig, CalibConfig};
    use crate::model::zoo;
    use crate::sim::{dadn::DadnSim, pra::PraSim, simulate_network, tetris::TetrisSim};

    /// §IV.B anchors: Tetris draws slightly more power than DaDN
    /// (paper: 1.08×) but PRA draws much more (paper: 3.37×).
    #[test]
    fn power_ordering_matches_paper() {
        let net = zoo::alexnet();
        let cfg = AccelConfig::default();
        let calib = CalibConfig::default();
        let d = simulate_network(&DadnSim, &net, &cfg, &calib, 1).unwrap();
        let t = simulate_network(&TetrisSim, &net, &cfg, &calib, 1).unwrap();
        let p = simulate_network(&PraSim, &net, &cfg, &calib, 1).unwrap();
        let power = |s: &crate::sim::NetworkSim| {
            network_energy(s, &calib).total_j() / s.time_s()
        };
        let (pd, pt, pp) = (power(&d), power(&t), power(&p));
        let tetris_rel = pt / pd;
        let pra_rel = pp / pd;
        assert!(
            (0.9..1.7).contains(&tetris_rel),
            "tetris power {tetris_rel}× DaDN (paper: 1.08×)"
        );
        assert!(
            (1.8..6.0).contains(&pra_rel),
            "PRA power {pra_rel}× DaDN (paper: 3.37×)"
        );
        assert!(pra_rel > tetris_rel);
    }

    /// §IV.B headline: Tetris EDP beats both baselines.
    #[test]
    fn edp_ordering_matches_paper() {
        let net = zoo::vgg16();
        let cfg = AccelConfig::default();
        let calib = CalibConfig::default();
        let edp_of = |a: &dyn crate::sim::Accelerator| {
            let s = simulate_network(a, &net, &cfg, &calib, 2).unwrap();
            crate::energy::edp(network_energy(&s, &calib).total_j(), s.time_s())
        };
        let d = edp_of(&DadnSim);
        let t = edp_of(&TetrisSim);
        let p = edp_of(&PraSim);
        assert!(t < d, "tetris EDP {t} !< dadn {d}");
        assert!(d < p, "dadn EDP {d} !< pra {p} (paper: PRA is 2.87× worse)");
    }

    #[test]
    fn energy_breakdown_sums() {
        let mut a = EnergyBreakdown { mults_j: 1.0, ..Default::default() };
        let b = EnergyBreakdown { adds_j: 2.0, leakage_j: 3.0, ..Default::default() };
        a.add(&b);
        assert_eq!(a.total_j(), 6.0);
    }
}
