//! Energy, power, area and EDP models (§IV.B, §IV.D).
//!
//! Replaces the paper's Synopsys DC + PrimeTime flow with an analytical
//! model: per-component switching energies (`config::EnergyTable`,
//! Horowitz-anchored) times the activity counts the simulators produce,
//! plus leakage; areas compose from `config::AreaTable`, which is
//! anchored directly on the paper's Table 2.

mod area;
mod power;

pub use area::{chip_area, AreaReport};
pub use power::{layer_energy, network_energy, EnergyBreakdown};

/// Energy-delay product in J·s — the paper's efficiency proxy (§IV.B).
pub fn edp(total_energy_j: f64, time_s: f64) -> f64 {
    total_energy_j * time_s
}

#[cfg(test)]
mod tests {
    #[test]
    fn edp_units() {
        assert_eq!(super::edp(2.0, 3.0), 6.0);
    }
}
