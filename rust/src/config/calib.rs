//! Calibration constants for the timing / energy / area models.
//!
//! The paper's numbers come from Vivado HLS RTL simulation (cycles),
//! Synopsys DC + PrimeTime on TSMC 65nm (power/area). We do not have that
//! toolchain; instead every model in `sim/`, `energy/` and `latency/` is
//! parameterized by the constants below. Each constant documents its
//! provenance: either a published anchor (the paper's own Table 2 /
//! Figure 1, Horowitz ISSCC'14 energy tables) or an explicit calibration
//! to the paper's reported ratios. Changing these moves absolute numbers;
//! the *orderings and crossovers* the benches check are robust across a
//! wide range (see `rust/tests/calib_robustness.rs`).

/// Per-component energy table, picojoules per operation.
///
/// Base numbers follow Horowitz, "Computing's energy problem" (ISSCC'14,
/// 45 nm) scaled ×1.7 to 65 nm (capacitance/voltage scaling); they enter
/// the power model of `energy::power`.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyTable {
    /// 16-bit fixed-point multiply (DaDN datapath).
    pub mult16_pj: f64,
    /// 16-bit fixed-point add (segment adders, adder trees).
    pub add16_pj: f64,
    /// 8-bit add.
    pub add8_pj: f64,
    /// Register file write (one 16-bit segment register).
    pub reg_write_pj: f64,
    /// SRAM read per 16-bit word (I/O activation/weight RAMs, 20KB/PE).
    pub sram_read_pj: f64,
    /// eDRAM read per 16-bit word.
    pub edram_read_pj: f64,
    /// Throttle-buffer / FIFO access per entry.
    pub fifo_pj: f64,
    /// Splitter decode (comparator + mux + pointer decode, Fig 6).
    pub splitter_pj: f64,
    /// Barrel shifter shift (PRA's multi-stage shifting).
    pub shifter_pj: f64,
    /// Static leakage per PE per cycle (all designs, same RAM macro).
    pub leakage_pe_pj: f64,
}

impl Default for EnergyTable {
    fn default() -> Self {
        Self {
            // Horowitz '14: 32b imul ≈ 3.1 pJ @45nm; 16b ≈ 1.0 pJ; ×1.7 → 65nm.
            mult16_pj: 1.7,
            // 16b add ≈ 0.05 pJ @45nm ×1.7.
            add16_pj: 0.085,
            add8_pj: 0.042,
            reg_write_pj: 0.03,
            // 8KB SRAM read ≈ 2.4 pJ/16b word @45nm ×1.7, 20KB macro.
            sram_read_pj: 4.0,
            edram_read_pj: 15.0,
            fifo_pj: 1.8,
            // comparator + 16:1 activation mux + 4b decode per slot.
            splitter_pj: 0.25,
            shifter_pj: 0.25,
            leakage_pe_pj: 45.0,
        }
    }
}

/// Per-component area table, mm² in TSMC 65nm.
///
/// Anchored directly on the paper's Table 2 (per-PE breakdown for Tetris
/// is given outright; DaDN/PRA compose from the shared components).
#[derive(Debug, Clone, PartialEq)]
pub struct AreaTable {
    /// I/O activation/weight RAMs, 20KB per PE (Table 2: 3.828 mm²).
    pub io_rams_mm2: f64,
    /// Throttle buffer, 5KB (Table 2: 0.957 mm²).
    pub throttle_mm2: f64,
    /// Splitter array, 16×16 (Table 2: 0.544 mm²).
    pub splitter_array_mm2: f64,
    /// Non-linear activation function unit (Table 2: 0.143 mm²).
    pub act_fn_mm2: f64,
    /// Segment adders, 16× (Table 2: 0.129 mm²).
    pub segment_adders_mm2: f64,
    /// Rear adder tree (Table 2: 0.008 mm²).
    pub adder_tree_mm2: f64,
    /// One 16-bit multiplier lane incl. its adder (DaDN datapath);
    /// calibrated so 16 DaDN PEs total 79.36 mm² (Table 2).
    pub mult_lane_mm2: f64,
    /// PRA bit-serial lane: serial IP + multi-stage shifter; calibrated
    /// with `pra_fifo_mm2` so 16 PRA PEs total 153.65 mm² (Table 2).
    pub pra_lane_mm2: f64,
    /// PRA's enlarged weight FIFOs ("16× more weight buffers", §IV.D).
    pub pra_fifo_mm2: f64,
}

impl Default for AreaTable {
    fn default() -> Self {
        Self {
            io_rams_mm2: 3.828,
            throttle_mm2: 0.957,
            splitter_array_mm2: 0.544,
            act_fn_mm2: 0.143,
            segment_adders_mm2: 0.129,
            adder_tree_mm2: 0.008,
            // DaDN PE = io_rams + act_fn + 16 mult lanes = 79.36/16 = 4.96
            //   → 16 lanes = 4.96 - 3.828 - 0.143 = 0.989 → 0.0618 per lane.
            mult_lane_mm2: 0.0618,
            // PRA PE = io_rams + act_fn + 16 lanes + big FIFOs
            //   = 153.65/16 = 9.603 → lanes+FIFOs = 5.632.
            pra_lane_mm2: 0.052,
            pra_fifo_mm2: 4.80,
        }
    }
}

/// Timing-model calibration.
#[derive(Debug, Clone, PartialEq)]
pub struct TimingCalib {
    /// Pipeline fill/drain cycles charged once per layer (all designs).
    pub pipeline_fill: u64,
    /// Rear-adder-tree drain charged once per lane completion on Tetris
    /// (log2(16) = 4 stages; pipelined, so amortized per *lane*, not per
    /// kneaded weight).
    pub tree_drain: u64,
    /// PRA synchronization-group width (weights that must finish their
    /// serial essential bits before the group advances; PRA'17 §5).
    pub pra_sync_group: usize,
    /// PRA throughput de-rate: fraction of peak the bit-serial frontend
    /// sustains once its weight FIFOs bandwidth-bound it. The paper's
    /// PRA-fp16 lands at ~1.15× DaDN (§IV.A) although an unconstrained
    /// essential-bit model would predict ~1.8×; the gap is FIFO refill
    /// stalls ("large buffers must be introduced", §IV.D). 0.68 reproduces
    /// the reported zone; see EXPERIMENTS.md.
    pub pra_frontend_derate: f64,
    /// Cycles for one fp16 MAC on DaDN (1 at 125 MHz — §IV setup).
    pub dadn_mac_cycles: u64,
    /// Tetris int8-mode frontend de-rate: halved splitters need twice
    /// the activation-window reads per cycle from the throttle buffer,
    /// whose ports don't double. The paper's int8 mode reaches 1.50×
    /// DaDN (Fig 8) rather than the "doubled in theory" 2×·fp16
    /// (§III.C.3); 0.74 reproduces that gap. See EXPERIMENTS.md §Fig8.
    pub int8_supply_derate: f64,
}

impl Default for TimingCalib {
    fn default() -> Self {
        Self {
            pipeline_fill: 8,
            tree_drain: 4,
            pra_sync_group: 16,
            pra_frontend_derate: 0.68,
            dadn_mac_cycles: 1,
            int8_supply_derate: 0.74,
        }
    }
}

/// Top-level calibration bundle.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CalibConfig {
    pub energy: EnergyTable,
    pub area: AreaTable,
    pub timing: TimingCalib,
}

impl CalibConfig {
    /// Load from a JSON file (experiment overrides). Absent fields keep
    /// their defaults so override files can be sparse.
    pub fn from_json_file(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let v = crate::util::json::parse(&text)
            .map_err(|e| crate::Error::Config(e.to_string()))?;
        Ok(Self::from_json(&v))
    }

    /// Deserialize with per-field defaulting.
    pub fn from_json(v: &crate::util::json::Json) -> Self {
        let mut c = CalibConfig::default();
        let e = v.get("energy");
        let f = |field: &crate::util::json::Json, dflt: f64| field.as_f64().unwrap_or(dflt);
        c.energy.mult16_pj = f(e.get("mult16_pj"), c.energy.mult16_pj);
        c.energy.add16_pj = f(e.get("add16_pj"), c.energy.add16_pj);
        c.energy.add8_pj = f(e.get("add8_pj"), c.energy.add8_pj);
        c.energy.reg_write_pj = f(e.get("reg_write_pj"), c.energy.reg_write_pj);
        c.energy.sram_read_pj = f(e.get("sram_read_pj"), c.energy.sram_read_pj);
        c.energy.edram_read_pj = f(e.get("edram_read_pj"), c.energy.edram_read_pj);
        c.energy.fifo_pj = f(e.get("fifo_pj"), c.energy.fifo_pj);
        c.energy.splitter_pj = f(e.get("splitter_pj"), c.energy.splitter_pj);
        c.energy.shifter_pj = f(e.get("shifter_pj"), c.energy.shifter_pj);
        c.energy.leakage_pe_pj = f(e.get("leakage_pe_pj"), c.energy.leakage_pe_pj);
        let a = v.get("area");
        c.area.io_rams_mm2 = f(a.get("io_rams_mm2"), c.area.io_rams_mm2);
        c.area.throttle_mm2 = f(a.get("throttle_mm2"), c.area.throttle_mm2);
        c.area.splitter_array_mm2 = f(a.get("splitter_array_mm2"), c.area.splitter_array_mm2);
        c.area.act_fn_mm2 = f(a.get("act_fn_mm2"), c.area.act_fn_mm2);
        c.area.segment_adders_mm2 = f(a.get("segment_adders_mm2"), c.area.segment_adders_mm2);
        c.area.adder_tree_mm2 = f(a.get("adder_tree_mm2"), c.area.adder_tree_mm2);
        c.area.mult_lane_mm2 = f(a.get("mult_lane_mm2"), c.area.mult_lane_mm2);
        c.area.pra_lane_mm2 = f(a.get("pra_lane_mm2"), c.area.pra_lane_mm2);
        c.area.pra_fifo_mm2 = f(a.get("pra_fifo_mm2"), c.area.pra_fifo_mm2);
        let t = v.get("timing");
        c.timing.pipeline_fill = t.get("pipeline_fill").as_u64().unwrap_or(c.timing.pipeline_fill);
        c.timing.tree_drain = t.get("tree_drain").as_u64().unwrap_or(c.timing.tree_drain);
        c.timing.pra_sync_group =
            t.get("pra_sync_group").as_usize().unwrap_or(c.timing.pra_sync_group);
        c.timing.pra_frontend_derate =
            f(t.get("pra_frontend_derate"), c.timing.pra_frontend_derate);
        c.timing.dadn_mac_cycles =
            t.get("dadn_mac_cycles").as_u64().unwrap_or(c.timing.dadn_mac_cycles);
        c.timing.int8_supply_derate = f(t.get("int8_supply_derate"), c.timing.int8_supply_derate);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tetris_pe_breakdown_sums_to_table2() {
        let a = AreaTable::default();
        let pe = a.io_rams_mm2
            + a.throttle_mm2
            + a.splitter_array_mm2
            + a.act_fn_mm2
            + a.segment_adders_mm2
            + a.adder_tree_mm2;
        // Table 2: 5.609 mm² per PE, ×16 = 89.76 mm².
        assert!((pe * 16.0 - 89.76).abs() < 0.2, "got {}", pe * 16.0);
    }

    #[test]
    fn sparse_json_overrides_only_named_fields() {
        let v = crate::util::json::parse(
            r#"{"timing": {"pra_frontend_derate": 0.5}, "energy": {"mult16_pj": 2.0}}"#,
        )
        .unwrap();
        let c = CalibConfig::from_json(&v);
        assert_eq!(c.timing.pra_frontend_derate, 0.5);
        assert_eq!(c.energy.mult16_pj, 2.0);
        // Untouched fields keep defaults.
        let d = CalibConfig::default();
        assert_eq!(c.timing.pra_sync_group, d.timing.pra_sync_group);
        assert_eq!(c.area.io_rams_mm2, d.area.io_rams_mm2);
    }
}
