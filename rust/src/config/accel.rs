//! Accelerator configuration (§III of the paper + §IV experimental setup).

use crate::util::json::Json;

/// Precision mode of the accelerator (§III.C.3).
///
/// * [`Mode::Fp16`] — 16-bit fixed-point weights; each splitter consumes
///   one kneaded weight per cycle and all 16 segment adders serve it.
/// * [`Mode::Int8`]  — 8-bit weights; each splitter is halved and consumes
///   *two* kneaded weights per cycle (upper 8 / lower 8 segment adders),
///   doubling throughput at equal kneading stride.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    Fp16,
    Int8,
}

impl std::str::FromStr for Mode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "fp16" => Ok(Mode::Fp16),
            "int8" => Ok(Mode::Int8),
            other => Err(format!("unknown mode `{other}` (want fp16|int8)")),
        }
    }
}

impl Mode {
    /// Number of magnitude bit positions a weight occupies.
    ///
    /// Weights are handled sign-magnitude (the sign rides with the
    /// activation dispatch, see `sac::splitter`): fp16 → bits 0..16,
    /// int8 → bits 0..8.
    pub const fn weight_bits(self) -> usize {
        match self {
            Mode::Fp16 => 16,
            Mode::Int8 => 8,
        }
    }

    /// Kneaded weights consumed per splitter per cycle.
    pub const fn kneaded_per_splitter(self) -> usize {
        match self {
            Mode::Fp16 => 1,
            Mode::Int8 => 2,
        }
    }

    /// Maximum representable magnitude (exclusive bound).
    pub const fn magnitude_bound(self) -> i32 {
        1 << (self.weight_bits() - 1) // keep one headroom bit: Q1.(B-1)
    }
}

impl std::fmt::Display for Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Mode::Fp16 => write!(f, "fp16"),
            Mode::Int8 => write!(f, "int8"),
        }
    }
}

/// Full accelerator configuration.
///
/// Defaults mirror the paper's evaluation setup (§IV): 16 PEs at 125 MHz,
/// 16 splitters and 16 segment adders per SAC unit, kneading stride 16.
#[derive(Debug, Clone, PartialEq)]
pub struct AccelConfig {
    /// Number of processing elements (SAC units for Tetris).
    pub pes: usize,
    /// Splitters per SAC unit (== multiplier lanes per DaDN PE).
    pub splitters_per_pe: usize,
    /// Segment adders per SAC unit (16 for fp16 coverage).
    pub segment_adders: usize,
    /// Kneading stride — weights kneaded per group (§III.B, Fig 11).
    pub ks: usize,
    /// Precision mode.
    pub mode: Mode,
    /// Clock frequency in MHz (125 in the paper, Xilinx Z7020 reference).
    pub freq_mhz: f64,
    /// Throttle-buffer capacity in kneaded weights per PE (5 KB in Table 2;
    /// a kneaded fp16 weight with KS=16 pointers is 16 slots × (1+4) bits
    /// = 80 bits = 10 B → ~512 entries).
    pub throttle_entries: usize,
    /// eDRAM read bandwidth in weight-words per cycle per PE.
    pub edram_words_per_cycle: usize,
    /// eDRAM access latency in cycles (refill stall when buffer empties).
    pub edram_latency: usize,
}

impl Default for AccelConfig {
    fn default() -> Self {
        Self {
            pes: 16,
            splitters_per_pe: 16,
            segment_adders: 16,
            ks: 16,
            mode: Mode::Fp16,
            freq_mhz: 125.0,
            throttle_entries: 512,
            edram_words_per_cycle: 32,
            edram_latency: 4,
        }
    }
}

impl AccelConfig {
    /// Pointer width in bits required by the kneading stride (the `p`
    /// field of Fig 6): ⌈log2 KS⌉.
    pub fn pointer_bits(&self) -> u32 {
        usize::BITS - (self.ks - 1).leading_zeros()
    }

    /// Lane-level parallelism: kneaded weights the whole chip consumes
    /// per cycle.
    pub fn kneaded_throughput(&self) -> usize {
        self.pes * self.splitters_per_pe * self.mode.kneaded_per_splitter()
    }

    /// MAC-equivalent throughput of the DaDN baseline with the same
    /// multiplier allocation (pairs per cycle).
    pub fn mac_throughput(&self) -> usize {
        self.pes * self.splitters_per_pe
    }

    /// Seconds per cycle.
    pub fn cycle_time_s(&self) -> f64 {
        1.0 / (self.freq_mhz * 1.0e6)
    }

    /// Serialize to JSON (config files, artifact metadata).
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("pes", Json::Num(self.pes as f64)),
            ("splitters_per_pe", Json::Num(self.splitters_per_pe as f64)),
            ("segment_adders", Json::Num(self.segment_adders as f64)),
            ("ks", Json::Num(self.ks as f64)),
            ("mode", Json::Str(self.mode.to_string())),
            ("freq_mhz", Json::Num(self.freq_mhz)),
            ("throttle_entries", Json::Num(self.throttle_entries as f64)),
            ("edram_words_per_cycle", Json::Num(self.edram_words_per_cycle as f64)),
            ("edram_latency", Json::Num(self.edram_latency as f64)),
        ])
    }

    /// Deserialize from JSON; absent fields keep defaults.
    pub fn from_json(v: &Json) -> crate::Result<Self> {
        let d = AccelConfig::default();
        let get_usize = |key: &str, dflt: usize| v.get(key).as_usize().unwrap_or(dflt);
        let mode = match v.get("mode").as_str() {
            Some(s) => s.parse::<Mode>().map_err(crate::Error::Config)?,
            None => d.mode,
        };
        let cfg = AccelConfig {
            pes: get_usize("pes", d.pes),
            splitters_per_pe: get_usize("splitters_per_pe", d.splitters_per_pe),
            segment_adders: get_usize("segment_adders", d.segment_adders),
            ks: get_usize("ks", d.ks),
            mode,
            freq_mhz: v.get("freq_mhz").as_f64().unwrap_or(d.freq_mhz),
            throttle_entries: get_usize("throttle_entries", d.throttle_entries),
            edram_words_per_cycle: get_usize("edram_words_per_cycle", d.edram_words_per_cycle),
            edram_latency: get_usize("edram_latency", d.edram_latency),
        };
        cfg.validate().map_err(crate::Error::Config)?;
        Ok(cfg)
    }

    /// Validate invariants; returns an error string on nonsense configs.
    pub fn validate(&self) -> Result<(), String> {
        if self.pes == 0 || self.splitters_per_pe == 0 {
            return Err("pes and splitters_per_pe must be > 0".into());
        }
        if self.ks < 2 || self.ks > 256 {
            return Err(format!("ks={} out of supported range 2..=256", self.ks));
        }
        if self.segment_adders < self.mode.weight_bits() {
            return Err(format!(
                "segment_adders={} cannot cover {}-bit weights",
                self.segment_adders,
                self.mode.weight_bits()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_setup() {
        let c = AccelConfig::default();
        assert_eq!(c.pes, 16);
        assert_eq!(c.splitters_per_pe, 16);
        assert_eq!(c.ks, 16);
        assert_eq!(c.freq_mhz, 125.0);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn pointer_bits_tracks_ks() {
        let mut c = AccelConfig::default();
        c.ks = 16;
        assert_eq!(c.pointer_bits(), 4);
        c.ks = 10;
        assert_eq!(c.pointer_bits(), 4);
        c.ks = 32;
        assert_eq!(c.pointer_bits(), 5);
        c.ks = 17;
        assert_eq!(c.pointer_bits(), 5);
        c.ks = 2;
        assert_eq!(c.pointer_bits(), 1);
    }

    #[test]
    fn int8_doubles_throughput() {
        let fp = AccelConfig { mode: Mode::Fp16, ..AccelConfig::default() };
        let i8 = AccelConfig { mode: Mode::Int8, ..AccelConfig::default() };
        assert_eq!(i8.kneaded_throughput(), 2 * fp.kneaded_throughput());
    }

    #[test]
    fn json_roundtrip() {
        let c = AccelConfig { ks: 24, mode: Mode::Int8, ..AccelConfig::default() };
        let j = c.to_json().to_string_pretty();
        let parsed = crate::util::json::parse(&j).unwrap();
        let c2 = AccelConfig::from_json(&parsed).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn from_json_uses_defaults_for_missing() {
        let v = crate::util::json::parse(r#"{"ks": 20}"#).unwrap();
        let c = AccelConfig::from_json(&v).unwrap();
        assert_eq!(c.ks, 20);
        assert_eq!(c.pes, 16);
        assert_eq!(c.mode, Mode::Fp16);
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let mut c = AccelConfig::default();
        c.ks = 1;
        assert!(c.validate().is_err());
        let mut c = AccelConfig::default();
        c.segment_adders = 8; // cannot cover fp16
        assert!(c.validate().is_err());
        c.mode = Mode::Int8; // 8 segment adders cover int8
        assert!(c.validate().is_ok());
    }
}
