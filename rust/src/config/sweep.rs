//! Parameter-sweep definitions (Fig 11's KS sweep and general grids).

use super::{AccelConfig, Mode};

/// One point of a sweep: a fully resolved accelerator config plus the
/// swept coordinate for labeling.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub label: String,
    pub config: AccelConfig,
}

/// The paper's Figure 11 sweep: KS from 10 to 32 for both modes.
#[derive(Debug, Clone)]
pub struct KsSweep {
    pub ks_values: Vec<usize>,
    pub modes: Vec<Mode>,
}

impl Default for KsSweep {
    fn default() -> Self {
        Self {
            // §IV.C: "We scale the KS from small (10 weights) to large (32)".
            ks_values: vec![10, 12, 14, 16, 20, 24, 28, 32],
            modes: vec![Mode::Fp16, Mode::Int8],
        }
    }
}

impl KsSweep {
    /// Expand into concrete configuration points over a base config.
    pub fn points(&self, base: &AccelConfig) -> Vec<SweepPoint> {
        let mut out = Vec::with_capacity(self.ks_values.len() * self.modes.len());
        for &mode in &self.modes {
            for &ks in &self.ks_values {
                let config = AccelConfig { ks, mode, ..base.clone() };
                out.push(SweepPoint { label: format!("{mode}-ks{ks}"), config });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_expands_cross_product() {
        let s = KsSweep::default();
        let pts = s.points(&AccelConfig::default());
        assert_eq!(pts.len(), s.ks_values.len() * 2);
        assert!(pts.iter().all(|p| p.config.validate().is_ok()));
        assert!(pts.iter().any(|p| p.label == "int8-ks32"));
    }
}
