//! Configuration system: accelerator parameters, calibration constants,
//! and sweep definitions.
//!
//! Everything the paper's evaluation varies is a field here, so benches,
//! examples, and the CLI all drive the same structs. Configs serialize to
//! JSON (`serde`) so experiment definitions can live in files.

mod accel;
mod calib;
mod sweep;

pub use accel::{AccelConfig, Mode};
pub use calib::CalibConfig;
pub use sweep::{KsSweep, SweepPoint};
