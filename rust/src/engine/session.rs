//! [`InferSession`] — the one client surface: `submit` returns a
//! [`Ticket`], `poll`/`wait` redeem it, `infer_batch` is the blocking
//! convenience. Sessions are cheap clones sharing the engine's
//! request channel and a common completion store, so any number of
//! submitter/drainer threads coexist.

use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferRequest, InferResponse, RequestId};
use crate::model::Tensor;

use super::registry::ModelId;
use super::serve::Completion;

/// Receipt for one submitted request: redeem with
/// [`InferSession::poll`] or [`InferSession::wait`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ticket {
    /// The model the request was routed to.
    pub model: ModelId,
    /// Engine-unique request id.
    pub id: RequestId,
}

/// Parked completions + redeemed-ticket bookkeeping, behind one lock.
///
/// Request ids are assigned sequentially per engine and mostly
/// complete near submission order, so redeemed ids compress to a
/// watermark (`all ids below are redeemed`) plus the out-of-order
/// stragglers above it — bounded state, unlike a grow-forever set.
struct HubStore {
    parked: HashMap<RequestId, Completion>,
    redeemed_below: RequestId,
    redeemed: BTreeSet<RequestId>,
}

impl HubStore {
    fn is_redeemed(&self, id: RequestId) -> bool {
        id < self.redeemed_below || self.redeemed.contains(&id)
    }

    fn mark_redeemed(&mut self, id: RequestId) {
        if id < self.redeemed_below {
            return;
        }
        if id == self.redeemed_below {
            self.redeemed_below += 1;
            while self.redeemed.remove(&self.redeemed_below) {
                self.redeemed_below += 1;
            }
        } else {
            self.redeemed.insert(id);
        }
    }
}

/// Completions arriving out of submission order park here until their
/// ticket is redeemed. One hub per engine, shared by all sessions.
pub(crate) struct ResponseHub {
    rx: Mutex<Receiver<Completion>>,
    store: Mutex<HubStore>,
    arrived: Condvar,
}

/// Unwrap a redeemed completion into the public result shape.
fn into_result(c: Completion) -> crate::Result<InferResponse> {
    match c {
        Completion::Done(r) => Ok(r),
        Completion::Failed { id, error } => Err(crate::Error::Coordinator(format!(
            "request {id} failed: {error}"
        ))),
    }
}

impl ResponseHub {
    pub fn new(rx: Receiver<Completion>) -> Self {
        Self {
            rx: Mutex::new(rx),
            store: Mutex::new(HubStore {
                parked: HashMap::new(),
                redeemed_below: 0,
                redeemed: BTreeSet::new(),
            }),
            arrived: Condvar::new(),
        }
    }

    fn stash(&self, c: Completion) {
        self.store.lock().unwrap().parked.insert(c.id(), c);
        self.arrived.notify_all();
    }

    /// Take `id`'s completion if parked, marking it redeemed. `Err`
    /// immediately on a double redeem.
    fn take(&self, id: RequestId) -> crate::Result<Option<Completion>> {
        let mut store = self.store.lock().unwrap();
        if store.is_redeemed(id) {
            return Err(crate::Error::Coordinator(format!(
                "ticket {id} was already redeemed"
            )));
        }
        match store.parked.remove(&id) {
            Some(c) => {
                store.mark_redeemed(id);
                Ok(Some(c))
            }
            None => Ok(None),
        }
    }

    fn mark_redeemed(&self, id: RequestId) {
        self.store.lock().unwrap().mark_redeemed(id);
    }

    /// Non-blocking: drain whatever is on the channel, then check the
    /// store. `Err` on a double redeem, a failed request, or when the
    /// engine has stopped and the response can no longer arrive.
    fn poll(&self, id: RequestId) -> crate::Result<Option<InferResponse>> {
        let mut disconnected = false;
        if let Ok(rx) = self.rx.try_lock() {
            loop {
                match rx.try_recv() {
                    Ok(c) => self.stash(c),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
        }
        match self.take(id)? {
            Some(c) => into_result(c).map(Some),
            None if disconnected => {
                Err(crate::Error::Coordinator("engine stopped".into()))
            }
            None => Ok(None),
        }
    }

    /// Block until `id` completes. One caller at a time drains the
    /// channel (stashing other tickets' completions); the rest wait on
    /// the store's condvar, so concurrent waiters never starve.
    fn wait(&self, id: RequestId) -> crate::Result<InferResponse> {
        const TICK: Duration = Duration::from_millis(20);
        loop {
            if let Some(c) = self.take(id)? {
                return into_result(c);
            }
            if let Ok(rx) = self.rx.try_lock() {
                match rx.recv_timeout(TICK) {
                    Ok(c) => {
                        if c.id() == id {
                            self.mark_redeemed(id);
                            // Others may be parked on the condvar for
                            // completions we have not drained yet.
                            self.arrived.notify_all();
                            return into_result(c);
                        }
                        self.stash(c);
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {
                        // A racing drainer may have stashed it between
                        // our `take` and the disconnect.
                        return match self.take(id)? {
                            Some(c) => into_result(c),
                            None => Err(crate::Error::Coordinator(
                                "engine stopped".into(),
                            )),
                        };
                    }
                }
            } else {
                let store = self.store.lock().unwrap();
                if store.parked.contains_key(&id) || store.is_redeemed(id) {
                    continue; // re-loop; take() resolves it
                }
                let (guard, _timed_out) = self.arrived.wait_timeout(store, TICK).unwrap();
                drop(guard);
            }
        }
    }
}

/// Per-model routing info sessions validate against.
pub(crate) struct SessionModel {
    pub name: String,
    pub in_c: Option<usize>,
    pub in_hw: Option<usize>,
}

/// State shared between an engine and every session it hands out.
pub(crate) struct SessionShared {
    /// `None` once the engine shut down — submissions then fail fast
    /// instead of hanging.
    pub req_tx: Mutex<Option<Sender<(usize, InferRequest)>>>,
    pub hub: ResponseHub,
    pub next_id: AtomicU64,
    pub metrics: Arc<Mutex<Metrics>>,
    pub models: Vec<SessionModel>,
}

/// Client handle to a running [`Engine`](super::Engine): one uniform
/// submit/poll surface over every registered model, whatever backend
/// serves it. Clone freely; clones share the ticket store.
#[derive(Clone)]
pub struct InferSession {
    shared: Arc<SessionShared>,
}

impl InferSession {
    pub(crate) fn new(shared: Arc<SessionShared>) -> Self {
        Self { shared }
    }

    /// Resolve a model name to its engine-local id.
    pub fn model_id(&self, name: &str) -> Option<ModelId> {
        self.shared.models.iter().position(|m| m.name == name)
    }

    /// Submit one (C, H, W) Q8.8 image to a model by name.
    pub fn submit(&self, model: &str, image: Tensor<i32>) -> crate::Result<Ticket> {
        let id = self.model_id(model).ok_or_else(|| {
            crate::Error::Config(format!("engine has no model `{model}`"))
        })?;
        self.submit_to(id, image)
    }

    /// Submit by [`ModelId`] (hot paths that resolved the name once).
    ///
    /// The full image shape is validated against the model's declared
    /// input here, up front: a worker-side execution failure would
    /// silently drop the whole dynamic batch (poisoning co-batched
    /// requests and leaving their `wait` calls hanging), so malformed
    /// submissions must never reach a lane.
    pub fn submit_to(&self, model: ModelId, image: Tensor<i32>) -> crate::Result<Ticket> {
        let meta = self.shared.models.get(model).ok_or_else(|| {
            crate::Error::Config(format!("model id {model} out of range"))
        })?;
        match *image.shape() {
            [c, h, w] => {
                if let Some(want) = meta.in_c {
                    if c != want {
                        return Err(crate::Error::Shape(format!(
                            "model `{}` takes {want} input channels, image has {c}",
                            meta.name
                        )));
                    }
                }
                if let Some(hw) = meta.in_hw {
                    if (h, w) != (hw, hw) {
                        return Err(crate::Error::Shape(format!(
                            "model `{}` takes {hw}×{hw} images, got {h}×{w}",
                            meta.name
                        )));
                    }
                }
            }
            _ => {
                return Err(crate::Error::Shape(
                    "submit takes one (C, H, W) image per request".into(),
                ))
            }
        }
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        self.shared
            .req_tx
            .lock()
            .unwrap()
            .as_ref()
            .ok_or_else(|| crate::Error::Coordinator("engine stopped".into()))?
            .send((model, InferRequest::new(id, image)))
            .map_err(|_| crate::Error::Coordinator("engine stopped".into()))?;
        Ok(Ticket { model, id })
    }

    /// Non-blocking check for a ticket's completion. `Ok(None)` while
    /// in flight; `Err` if the request failed at the backend, the
    /// ticket was already redeemed, or the engine stopped.
    pub fn poll(&self, ticket: &Ticket) -> crate::Result<Option<InferResponse>> {
        self.shared.hub.poll(ticket.id)
    }

    /// Block until a ticket completes. A backend-side failure
    /// completes the ticket with a typed error (never a hang), and
    /// redeeming the same ticket twice errors immediately.
    pub fn wait(&self, ticket: &Ticket) -> crate::Result<InferResponse> {
        self.shared.hub.wait(ticket.id)
    }

    /// Blocking convenience: submit every image to one model and wait
    /// for all of them, preserving submission order. The engine still
    /// batches them dynamically under the hood.
    pub fn infer_batch(
        &self,
        model: &str,
        images: &[Tensor<i32>],
    ) -> crate::Result<Vec<InferResponse>> {
        let id = self.model_id(model).ok_or_else(|| {
            crate::Error::Config(format!("engine has no model `{model}`"))
        })?;
        let tickets: Vec<Ticket> = images
            .iter()
            .map(|img| self.submit_to(id, img.clone()))
            .collect::<crate::Result<_>>()?;
        tickets.iter().map(|t| self.wait(t)).collect()
    }

    /// Snapshot the engine's aggregate serving metrics (latency
    /// percentiles included — see `Metrics::latency_percentiles`).
    pub fn metrics(&self) -> Metrics {
        self.shared.metrics.lock().unwrap().clone()
    }
}
