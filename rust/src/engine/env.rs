//! The single place process environment is read.
//!
//! Every `TETRIS_*` knob used to be parsed ad hoc at its consumption
//! site (`coordinator::backend`, `util::pool`, `util::bench`,
//! `util::prop`, two bench targets), each with its own silent
//! fallback-on-parse-error. They are now **documented fallbacks**
//! resolved here, in exactly one place, with typed parsing; a value
//! that is present but unparsable logs one warning per variable per
//! process (instead of being silently swallowed) and then falls back.
//!
//! Typed [`EngineBuilder`](super::EngineBuilder) options take
//! precedence over every variable below — the environment is only
//! consulted where no explicit option was given.
//!
//! | Variable | Type | Default | Consumed by |
//! |----------|------|---------|-------------|
//! | `TETRIS_MEM_BUDGET_MB`  | `u64` (MiB, min 1)  | 256  | serving fused-tile height ([`EngineBuilder::mem_budget_mb`](super::EngineBuilder::mem_budget_mb) fallback; `coordinator::SacBackend::new`) |
//! | `TETRIS_THREADS`        | `usize` (min 1)     | host parallelism, capped at 16 | `util::pool::worker_count` ([`EngineBuilder::workers`](super::EngineBuilder::workers) fallback) |
//! | `TETRIS_BENCH_SECONDS`  | `f64` (seconds)     | 0.6  | `util::bench::BenchConfig` measurement window |
//! | `TETRIS_BENCH_JSON`     | path                | none | `util::bench::Harness::json_target` sink (CLI `--json` wins) |
//! | `TETRIS_BENCH_CSV`      | path (directory)    | none | per-bench CSV dumps (`benches/hotpath.rs`, `benches/table1_bits.rs`) |
//! | `TETRIS_PROP_CASES`     | `usize`             | 256  | `util::prop::PropConfig` case count |
//! | `TETRIS_LISTEN`         | `SocketAddr`        | none | `tetris shard` bind address (CLI `--listen` wins) |
//! | `TETRIS_SHARDS`         | `usize` (min 1)     | 2    | `cluster::SupervisorConfig::default` shard count |
//! | `TETRIS_RPC_TIMEOUT_MS` | `u64` (ms, min 1)   | 5000 | `cluster::RouterConfig::default` per-request deadline |

use std::collections::BTreeSet;
use std::net::SocketAddr;
use std::path::PathBuf;
use std::str::FromStr;
use std::sync::Mutex;
use std::time::Duration;

/// Default serving feature-map budget when `TETRIS_MEM_BUDGET_MB` is
/// unset (mirrors the pre-engine hardcoded fallback).
pub const DEFAULT_MEM_BUDGET_MB: u64 = 256;

/// Default bench measurement window in seconds.
pub const DEFAULT_BENCH_SECONDS: f64 = 0.6;

/// Default property-test case count.
pub const DEFAULT_PROP_CASES: usize = 256;

/// Default shard count when `TETRIS_SHARDS` is unset.
pub const DEFAULT_SHARDS: usize = 2;

/// Default router per-request deadline when `TETRIS_RPC_TIMEOUT_MS`
/// is unset.
pub const DEFAULT_RPC_TIMEOUT_MS: u64 = 5000;

/// Variables that already logged a parse warning this process.
static WARNED: Mutex<BTreeSet<&'static str>> = Mutex::new(BTreeSet::new());

/// Parse an *optional* raw value: `None` when the variable is unset or
/// unparsable. Pure — unit-testable without touching the process
/// environment; the warning side effect lives in [`warn_once`].
fn parse_opt<T: FromStr>(var: &'static str, raw: Option<&str>) -> Result<Option<T>, String> {
    match raw {
        None => Ok(None),
        Some(s) => match s.trim().parse::<T>() {
            Ok(v) => Ok(Some(v)),
            Err(_) => Err(format!(
                "{var}={s:?} is not a valid {}; using the default",
                std::any::type_name::<T>()
            )),
        },
    }
}

/// Log a parse failure once per variable per process.
fn warn_once(var: &'static str, msg: &str) {
    if WARNED.lock().unwrap().insert(var) {
        eprintln!("tetris: ignoring {msg}");
    }
}

/// Read + parse one variable, warning once on a present-but-invalid
/// value and returning `None` for it (callers supply the default).
fn read<T: FromStr>(var: &'static str) -> Option<T> {
    let raw = std::env::var(var).ok();
    match parse_opt::<T>(var, raw.as_deref()) {
        Ok(v) => v,
        Err(msg) => {
            warn_once(var, &msg);
            None
        }
    }
}

/// `TETRIS_MEM_BUDGET_MB`: per-worker serving feature-map budget in
/// MiB (minimum 1), defaulting to [`DEFAULT_MEM_BUDGET_MB`].
pub fn mem_budget_mb() -> u64 {
    read::<u64>("TETRIS_MEM_BUDGET_MB")
        .unwrap_or(DEFAULT_MEM_BUDGET_MB)
        .max(1)
}

/// [`mem_budget_mb`] in bytes.
pub fn mem_budget_bytes() -> u64 {
    mem_budget_mb() * 1024 * 1024
}

/// `TETRIS_THREADS`: explicit worker-thread override (minimum 1), or
/// `None` to let `util::pool::worker_count` use the host parallelism.
pub fn threads() -> Option<usize> {
    read::<usize>("TETRIS_THREADS").map(|n| n.max(1))
}

/// `TETRIS_BENCH_SECONDS`: bench measurement window.
pub fn bench_seconds() -> f64 {
    read::<f64>("TETRIS_BENCH_SECONDS").unwrap_or(DEFAULT_BENCH_SECONDS)
}

/// `TETRIS_BENCH_JSON`: bench JSON sink (paths are not validated —
/// the write reports its own error).
pub fn bench_json() -> Option<PathBuf> {
    std::env::var("TETRIS_BENCH_JSON").ok().map(PathBuf::from)
}

/// `TETRIS_BENCH_CSV`: directory for per-bench CSV dumps.
pub fn bench_csv_dir() -> Option<PathBuf> {
    std::env::var("TETRIS_BENCH_CSV").ok().map(PathBuf::from)
}

/// `TETRIS_PROP_CASES`: property-test case count.
pub fn prop_cases() -> usize {
    read::<usize>("TETRIS_PROP_CASES").unwrap_or(DEFAULT_PROP_CASES)
}

/// `TETRIS_LISTEN`: default bind address for `tetris shard` when no
/// `--listen` flag is given. `None` when unset or unparsable (same
/// warn-once contract as the numeric knobs).
pub fn listen() -> Option<SocketAddr> {
    read::<SocketAddr>("TETRIS_LISTEN")
}

/// `TETRIS_SHARDS`: supervisor shard count (minimum 1), defaulting to
/// [`DEFAULT_SHARDS`].
pub fn shards() -> usize {
    read::<usize>("TETRIS_SHARDS").unwrap_or(DEFAULT_SHARDS).max(1)
}

/// `TETRIS_RPC_TIMEOUT_MS`: router per-request deadline in
/// milliseconds (minimum 1), defaulting to [`DEFAULT_RPC_TIMEOUT_MS`].
pub fn rpc_timeout_ms() -> u64 {
    read::<u64>("TETRIS_RPC_TIMEOUT_MS")
        .unwrap_or(DEFAULT_RPC_TIMEOUT_MS)
        .max(1)
}

/// [`rpc_timeout_ms`] as a [`Duration`].
pub fn rpc_timeout() -> Duration {
    Duration::from_millis(rpc_timeout_ms())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absent_values_fall_back() {
        assert_eq!(parse_opt::<u64>("X", None).unwrap(), None);
        assert_eq!(parse_opt::<usize>("X", None).unwrap(), None);
    }

    #[test]
    fn valid_values_parse_typed() {
        assert_eq!(parse_opt::<u64>("X", Some("512")).unwrap(), Some(512));
        assert_eq!(parse_opt::<usize>("X", Some(" 8 ")).unwrap(), Some(8));
        assert_eq!(parse_opt::<f64>("X", Some("0.25")).unwrap(), Some(0.25));
        assert_eq!(
            parse_opt::<SocketAddr>("X", Some("127.0.0.1:7000")).unwrap(),
            Some("127.0.0.1:7000".parse().unwrap())
        );
    }

    #[test]
    fn invalid_values_error_instead_of_silently_vanishing() {
        let err = parse_opt::<u64>("TETRIS_MEM_BUDGET_MB", Some("lots")).unwrap_err();
        assert!(err.contains("TETRIS_MEM_BUDGET_MB"), "{err}");
        assert!(parse_opt::<usize>("T", Some("-3")).is_err());
        assert!(parse_opt::<f64>("T", Some("")).is_err());
        assert!(parse_opt::<SocketAddr>("TETRIS_LISTEN", Some("not-an-addr")).is_err());
        assert!(parse_opt::<SocketAddr>("TETRIS_LISTEN", Some("127.0.0.1")).is_err(), "no port");
    }

    #[test]
    fn warn_once_is_once() {
        // Second warning for the same variable is suppressed; a
        // different variable still warns. (Observable only via the
        // WARNED set — stderr is not captured here.)
        warn_once("TETRIS_TEST_ONLY_A", "a");
        assert!(!WARNED.lock().unwrap().insert("TETRIS_TEST_ONLY_A"));
        warn_once("TETRIS_TEST_ONLY_B", "b");
        assert!(!WARNED.lock().unwrap().insert("TETRIS_TEST_ONLY_B"));
    }

    #[test]
    fn defaults_are_sane() {
        // These read the live environment; CI leaves the knobs unset,
        // and when set they must still be ≥ the documented minima.
        assert!(mem_budget_mb() >= 1);
        assert!(prop_cases() >= 1);
        assert!(bench_seconds() > 0.0);
        assert!(shards() >= 1);
        assert!(rpc_timeout_ms() >= 1);
        assert_eq!(rpc_timeout(), Duration::from_millis(rpc_timeout_ms()));
        if let Some(t) = threads() {
            assert!(t >= 1);
        }
    }
}
