//! [`EngineBuilder`] — every serving knob as a typed option, resolved
//! in one place.
//!
//! Environment variables are demoted to documented fallbacks (see
//! [`env`](super::env)): an explicit builder option always wins, and
//! the environment is read exactly once per `build`, here.

use std::path::PathBuf;
use std::time::Duration;

use crate::coordinator::batcher::BatchPolicy;
use crate::model::{LoadedWeights, Network};
use crate::plan::{Kernel, Walk};
use crate::runtime::quantized::PIPELINE_KS;
use crate::util::pool::worker_count;

use super::registry::{compile_sac, pjrt_lane, ModelSpec};
use super::serve::{EngineCore, ModelLane};
use super::{env, Engine};

/// Which backend family serves every model of an engine. Callers pick
/// a kind here and never branch on backend type again — the
/// submit/poll surface is identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// The pure-rust kneaded-SAC plan executor: models are registered
    /// as declared networks + weights, compiled once, and shared by
    /// every worker through one `Arc`'d plan.
    #[default]
    Sac,
    /// The AOT XLA golden model through PJRT. Serves the `golden`
    /// model from the configured artifacts directory; PJRT handles
    /// are thread-pinned, so each worker compiles its own executable.
    /// Requires the `xla` + `xla-vendored` cargo features.
    Pjrt,
}

/// Typed configuration + model registry for an [`Engine`].
///
/// ```no_run
/// use tetris::coordinator::SacBackend;
/// use tetris::engine::Engine;
/// use tetris::model::zoo;
///
/// let weights = SacBackend::synthetic_weights(7)?;
/// let engine = Engine::builder()
///     .workers(4)
///     .mem_budget_mb(128)
///     .max_batch(8)
///     .register("tiny", zoo::tiny_cnn(), weights)
///     .build()?;
/// # Ok::<(), tetris::Error>(())
/// ```
pub struct EngineBuilder {
    backend: BackendKind,
    workers: Option<usize>,
    mem_budget_mb: Option<u64>,
    tile_rows: Option<usize>,
    walk: Option<Walk>,
    policy: BatchPolicy,
    ks: usize,
    auto_tune: bool,
    skip_zero_activations: bool,
    kernel: Option<Kernel>,
    artifacts_dir: PathBuf,
    specs: Vec<ModelSpec>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl EngineBuilder {
    pub fn new() -> Self {
        Self {
            backend: BackendKind::Sac,
            workers: None,
            mem_budget_mb: None,
            tile_rows: None,
            walk: None,
            policy: BatchPolicy::default(),
            ks: PIPELINE_KS,
            auto_tune: true,
            skip_zero_activations: false,
            kernel: None,
            artifacts_dir: PathBuf::from("artifacts"),
            specs: Vec::new(),
        }
    }

    /// Backend family (default [`BackendKind::Sac`]).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.backend = kind;
        self
    }

    /// Worker threads. Fallback: `TETRIS_THREADS`, else the host
    /// parallelism capped at 16 (see [`env::threads`]).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = Some(workers.max(1));
        self
    }

    /// Per-worker feature-map memory budget in MiB, which serving
    /// turns into a fused-tile height per model. Fallback:
    /// `TETRIS_MEM_BUDGET_MB`, else 256 (see [`env::mem_budget_mb`]).
    pub fn mem_budget_mb(mut self, mb: u64) -> Self {
        self.mem_budget_mb = Some(mb.max(1));
        self
    }

    /// Pin the fused-tile height directly instead of deriving it from
    /// the memory budget (0 = materialize full maps).
    pub fn tile_rows(mut self, rows: usize) -> Self {
        self.tile_rows = Some(rows);
        self
    }

    /// Pin every registered model to one executor walk instead of the
    /// default policy (batch-vs-workers, with a budget-demanded
    /// fallover to [`Walk::Pipelined`] when not even the streaming
    /// walk's peak fits the memory budget). When a walk is pinned and
    /// the tile height is not, the tile is sized with that walk's
    /// peak-bytes estimator.
    pub fn walk(mut self, walk: Walk) -> Self {
        self.walk = Some(walk);
        self
    }

    /// Dynamic batching policy (bound + deadline together).
    pub fn batch_policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Dynamic batcher upper bound.
    pub fn max_batch(mut self, max_batch: usize) -> Self {
        self.policy.max_batch = max_batch;
        self
    }

    /// Dynamic batcher deadline.
    pub fn max_wait(mut self, max_wait: Duration) -> Self {
        self.policy.max_wait = max_wait;
        self
    }

    /// Kneading stride models are compiled with (default 16, the
    /// paper setup; values are KS-invariant — see DESIGN.md I3).
    pub fn kneading_stride(mut self, ks: usize) -> Self {
        self.ks = ks;
        self
    }

    /// Schedule auto-tuning (default **on**): each registration's
    /// walk/tile schedule comes from the memoized `plan::tune` search
    /// — feasibility-first over walk × tile candidates, with the
    /// budget-demanded [`Walk::Pipelined`] fallover and an explicit
    /// over-budget diagnostic. `auto_tune(false)` reverts to plain
    /// budget-ladder sizing: the walk is never pinned for you and no
    /// fallover runs (explicit [`EngineBuilder::walk`] /
    /// [`EngineBuilder::tile_rows`] pins are honored either way).
    pub fn auto_tune(mut self, enabled: bool) -> Self {
        self.auto_tune = enabled;
        self
    }

    /// Activation-aware SAC skipping (default **off**): every
    /// registered plan executes with the zero-activation skip lane
    /// armed — all-zero post-ReLU input rows/windows skip their SAC
    /// walk and are counted in the serving skip metrics
    /// ([`InferSession::metrics`](super::InferSession::metrics)).
    /// Bit-exact by construction (DESIGN.md §Activation skipping):
    /// logits never change, only cycles and counters do.
    pub fn skip_zero_activations(mut self, enabled: bool) -> Self {
        self.skip_zero_activations = enabled;
        self
    }

    /// Pin every registered plan's conv inner loop
    /// ([`Kernel::Decoded`] is the compiled default — the compile-time
    /// decoded schedule with register-blocked strips;
    /// [`Kernel::Legacy`] reverts to the per-pixel splitter walk).
    /// Bit-exact either way (DESIGN.md §Decoded-lane kernel): the
    /// kernel moves host wall time only, never logits or the serving
    /// skip/energy counters. An explicit `ExecOpts::kernel` still
    /// overrides per call.
    pub fn kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = Some(kernel);
        self
    }

    /// Artifacts directory for [`BackendKind::Pjrt`] (default
    /// `artifacts`).
    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    /// Register one model: a declared network plus its weight set,
    /// compiled exactly once at [`EngineBuilder::build`]. SAC engines
    /// only — the PJRT backend serves the AOT `golden` artifact.
    pub fn register(
        mut self,
        name: impl Into<String>,
        network: Network,
        weights: LoadedWeights,
    ) -> Self {
        self.specs.push(ModelSpec::new(name, network, weights));
        self
    }

    /// Register a prebuilt [`ModelSpec`].
    pub fn register_spec(mut self, spec: ModelSpec) -> Self {
        self.specs.push(spec);
        self
    }

    /// Resolve every option (explicit value, else documented env
    /// fallback), compile each registered model exactly once, spawn
    /// the shared worker pool, and hand back the running [`Engine`].
    pub fn build(self) -> crate::Result<Engine> {
        if self.policy.max_batch == 0 {
            return Err(crate::Error::Config("max_batch must be positive".into()));
        }
        let workers = self.workers.unwrap_or_else(worker_count).max(1);
        let budget_bytes =
            self.mem_budget_mb.unwrap_or_else(env::mem_budget_mb).max(1) * 1024 * 1024;

        let mut metas = Vec::new();
        let mut lanes = Vec::new();
        match self.backend {
            BackendKind::Sac => {
                if self.specs.is_empty() {
                    return Err(crate::Error::Config(
                        "engine has no registered models — call `register` before `build`"
                            .into(),
                    ));
                }
                for spec in self.specs {
                    if metas.iter().any(|m: &super::ModelMeta| m.name() == spec.name) {
                        return Err(crate::Error::Config(format!(
                            "model `{}` registered twice",
                            spec.name
                        )));
                    }
                    let (meta, factory) = compile_sac(
                        spec,
                        self.ks,
                        budget_bytes,
                        self.tile_rows,
                        workers,
                        self.walk,
                        self.auto_tune,
                        self.skip_zero_activations,
                        self.kernel,
                    )?;
                    lanes.push(ModelLane { factory });
                    metas.push(meta);
                }
            }
            BackendKind::Pjrt => {
                if !self.specs.is_empty() {
                    return Err(crate::Error::Config(
                        "PJRT engines serve the AOT `golden` artifact model; \
                         network registration is SAC-only"
                            .into(),
                    ));
                }
                let (meta, factory) = pjrt_lane(&self.artifacts_dir)?;
                lanes.push(ModelLane { factory });
                metas.push(meta);
            }
        }

        let (core, resp_rx) = EngineCore::start(workers, self.policy, lanes)?;
        Ok(Engine::from_parts(core, resp_rx, metas, workers))
    }
}
