//! The engine façade — the crate's single serving entry point.
//!
//! The paper's pitch is that kneading + SAC pays off when the whole
//! pipeline is organized around it: compile once (§III.B), stream the
//! kneaded form, schedule in a front end rather than at call sites.
//! This module is that front end for serving:
//!
//! * [`EngineBuilder`] — every knob as a typed option (memory budget,
//!   tile rows, executor walk, worker threads, batch policy, kneading
//!   stride), resolved in one place; environment variables are demoted
//!   to documented fallbacks ([`env`]). When the budget cannot hold
//!   even the streaming walk's peak, compilation pins the model to the
//!   whole-network **pipelined** walk (depth-independent peak memory)
//!   and reports it via [`ModelMeta::walk`].
//! * [`Engine`] — owns a **model registry**: several networks (the
//!   whole zoo, at any scale) are registered, compiled exactly once
//!   each, and served concurrently from one shared worker pool.
//! * [`InferSession`] — the uniform client surface:
//!   `submit(model, image) → Ticket`, `poll`/`wait`, and a blocking
//!   `infer_batch` convenience; `metrics()` reports throughput and
//!   exact latency percentiles.
//! * [`BackendKind`] — one constructor path over both backends: the
//!   pure-rust kneaded-SAC plan executor and the PJRT/XLA golden
//!   model. Callers never branch on backend type.
//!
//! The older entry points — `coordinator::Server::{start,
//! start_shared}` and raw `CompiledNetwork` handles — remain as thin
//! shims over this engine's core (see DESIGN.md §Engine API for the
//! deprecation map).
//!
//! ```no_run
//! use tetris::coordinator::SacBackend;
//! use tetris::engine::Engine;
//! use tetris::model::{zoo, Tensor};
//!
//! let engine = Engine::builder()
//!     .workers(2)
//!     .register("tiny", zoo::tiny_cnn(), SacBackend::synthetic_weights(7)?)
//!     .build()?;
//! let session = engine.session();
//! let ticket = session.submit("tiny", Tensor::zeros(&[1, 16, 16]))?;
//! let response = session.wait(&ticket)?;
//! println!("class {} in {:.0} µs", response.argmax, response.latency_us);
//! engine.shutdown();
//! # Ok::<(), tetris::Error>(())
//! ```

pub mod env;

mod builder;
mod registry;
pub(crate) mod serve;
mod session;

pub use builder::{BackendKind, EngineBuilder};
pub use registry::{ModelId, ModelMeta, ModelSpec};
pub use session::{InferSession, Ticket};

use std::sync::atomic::AtomicU64;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};

use crate::coordinator::metrics::Metrics;

use serve::{Completion, EngineCore};
use session::{ResponseHub, SessionModel, SessionShared};

/// A running serving engine: model registry + shared worker pool.
///
/// Build with [`Engine::builder`]; talk to it through
/// [`Engine::session`] handles. Dropping or [`Engine::shutdown`]ting
/// the engine drains in-flight work and joins every thread;
/// outstanding sessions then fail fast instead of hanging.
pub struct Engine {
    shared: Arc<SessionShared>,
    models: Vec<ModelMeta>,
    workers: usize,
    core: EngineCore,
}

impl Engine {
    /// Start configuring an engine.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    pub(crate) fn from_parts(
        core: EngineCore,
        resp_rx: Receiver<Completion>,
        models: Vec<ModelMeta>,
        workers: usize,
    ) -> Self {
        let shared = Arc::new(SessionShared {
            req_tx: Mutex::new(Some(core.sender())),
            hub: ResponseHub::new(resp_rx),
            next_id: AtomicU64::new(0),
            metrics: core.metrics_handle(),
            models: models
                .iter()
                .map(|m| SessionModel {
                    name: m.name().to_string(),
                    in_c: m.in_c,
                    in_hw: m.in_hw,
                })
                .collect(),
        });
        Self { shared, models, workers, core }
    }

    /// A client handle. Sessions are cheap clones; all of an engine's
    /// sessions share one completion store, so tickets may be redeemed
    /// from any of them.
    pub fn session(&self) -> InferSession {
        InferSession::new(Arc::clone(&self.shared))
    }

    /// Registered models, registration order (= [`ModelId`] order).
    pub fn models(&self) -> &[ModelMeta] {
        &self.models
    }

    /// Resolve a model name.
    pub fn model_id(&self, name: &str) -> Option<ModelId> {
        self.models.iter().position(|m| m.name() == name)
    }

    /// Worker threads serving the pool.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Snapshot aggregate serving metrics.
    pub fn metrics(&self) -> Metrics {
        self.core.metrics()
    }

    /// Stop accepting requests, drain every lane, join all threads,
    /// and return the final metrics. In-flight responses remain
    /// redeemable from the completion store until sessions drop.
    pub fn shutdown(mut self) -> Metrics {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Metrics {
        // Invalidate session submitters first: the dispatcher only
        // sees a closed channel once every sender is gone.
        *self.shared.req_tx.lock().unwrap() = None;
        self.core.shutdown()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}
