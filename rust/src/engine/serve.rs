//! The engine's shared serving core: one request channel, one dynamic
//! batcher **per registered model**, one worker pool serving every
//! model.
//!
//! This is the routing loop that used to live inside
//! `coordinator::Server`, generalized from one model to a registry:
//! requests are tagged with a [`ModelId`](super::ModelId), the
//! dispatcher batches each model's queue independently (same
//! [`BatchPolicy`] bounds), and released batches round-robin across
//! workers — so several compiled networks are served concurrently from
//! one pool without per-model threads. `coordinator::Server` is now a
//! thin shim over a single-lane core, which keeps its long-standing
//! behavior tests (exactly-once delivery, value transparency I6)
//! pinning this code.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::backend::InferBackend;
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InferRequest, InferResponse, RequestId};
use crate::model::Tensor;

/// Terminal outcome of one request. A batch that fails at the backend
/// (a PJRT runtime error, a result-count mismatch) completes every
/// one of its requests as [`Completion::Failed`] instead of silently
/// dropping them — so a client blocked in `InferSession::wait` gets a
/// typed error, never a permanent hang.
pub(crate) enum Completion {
    Done(InferResponse),
    Failed { id: RequestId, error: String },
}

impl Completion {
    pub fn id(&self) -> RequestId {
        match self {
            Completion::Done(r) => r.id,
            Completion::Failed { id, .. } => *id,
        }
    }
}

/// Per-worker backend constructor for one model. Called once per
/// worker thread, **on** that thread — so backends need not be `Send`
/// (PJRT handles are thread-pinned). Cheap-clone backends (e.g.
/// `SacBackend` over an `Arc`'d plan) should capture a prototype and
/// clone it, so W workers share one compile.
pub(crate) type BackendFactory =
    Arc<dyn Fn(usize) -> crate::Result<Box<dyn InferBackend>> + Send + Sync>;

/// One registered model's serving lane: the per-worker backend
/// factory. Lane order is [`ModelId`](super::ModelId) order — display
/// names live in the engine's `ModelMeta` registry.
pub(crate) struct ModelLane {
    pub factory: BackendFactory,
}

/// The running core: submit tagged requests, drain one response
/// channel, snapshot metrics, shut down. The response receiver is
/// returned by [`EngineCore::start`] so the owner decides how to drain
/// it (the `Server` shim blocks on it directly; `engine::InferSession`
/// parks out-of-order completions in a ticket store).
pub(crate) struct EngineCore {
    req_tx: Option<Sender<(usize, InferRequest)>>,
    metrics: Arc<Mutex<Metrics>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl EngineCore {
    /// Spawn the worker pool and dispatcher. Every worker constructs
    /// one backend per lane via the lane's factory, on the worker's
    /// own thread.
    pub fn start(
        workers: usize,
        policy: BatchPolicy,
        lanes: Vec<ModelLane>,
    ) -> crate::Result<(Self, Receiver<Completion>)> {
        assert!(workers > 0, "engine needs at least one worker");
        assert!(!lanes.is_empty(), "engine needs at least one model lane");
        let models = lanes.len();
        let (req_tx, req_rx) = channel::<(usize, InferRequest)>();
        let (resp_tx, resp_rx) = channel::<Completion>();
        let metrics = Arc::new(Mutex::new(Metrics::new()));

        let factories: Arc<Vec<BackendFactory>> =
            Arc::new(lanes.into_iter().map(|l| l.factory).collect());
        let mut batch_txs = Vec::new();
        let mut worker_handles = Vec::new();
        // Workers report backend construction before entering their
        // serve loop, so a failed factory (a per-thread PJRT compile,
        // say) fails `start` instead of leaving a silently dead worker
        // the dispatcher keeps routing ~1/W of all batches to.
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        for w in 0..workers {
            let (btx, brx) = channel::<(usize, Vec<InferRequest>)>();
            batch_txs.push(btx);
            let resp_tx = resp_tx.clone();
            let metrics = Arc::clone(&metrics);
            let factories = Arc::clone(&factories);
            let ready_tx = ready_tx.clone();
            worker_handles.push(std::thread::spawn(move || {
                let mut backends: Vec<Box<dyn InferBackend>> = Vec::with_capacity(factories.len());
                for f in factories.iter() {
                    match f(w) {
                        Ok(b) => backends.push(b),
                        Err(e) => {
                            let _ = ready_tx.send(Err(format!("worker {w}: {e}")));
                            return;
                        }
                    }
                }
                let _ = ready_tx.send(Ok(()));
                drop(ready_tx);
                while let Ok((m, batch)) = brx.recv() {
                    let ids: Vec<RequestId> = batch.iter().map(|r| r.id).collect();
                    if let Err(e) = run_batch(&mut *backends[m], batch, &resp_tx, &metrics) {
                        // Complete every co-batched request with the
                        // error — clients get a typed failure instead
                        // of waiting forever on a dropped batch.
                        eprintln!("worker {w}: batch failed: {e}");
                        for id in ids {
                            let _ = resp_tx
                                .send(Completion::Failed { id, error: e.to_string() });
                        }
                    }
                }
            }));
        }
        drop(ready_tx);
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(msg)) => {
                    // Unwind: close every worker's batch channel and
                    // join the ones that did come up.
                    drop(batch_txs);
                    for h in worker_handles {
                        let _ = h.join();
                    }
                    return Err(crate::Error::Coordinator(format!(
                        "backend init failed: {msg}"
                    )));
                }
                Err(_) => {
                    drop(batch_txs);
                    for h in worker_handles {
                        let _ = h.join();
                    }
                    return Err(crate::Error::Coordinator(
                        "a worker died before reporting readiness".into(),
                    ));
                }
            }
        }

        // Dispatcher: one batcher per model, releases round-robin to
        // the shared worker pool.
        let dispatcher = std::thread::spawn(move || {
            let mut batchers: Vec<Batcher> =
                (0..models).map(|_| Batcher::new(policy.clone())).collect();
            let mut next_worker = 0usize;
            let mut open = true;
            while open || batchers.iter().map(Batcher::pending).sum::<usize>() > 0 {
                // Drain the request channel without blocking past the
                // batching deadline.
                loop {
                    match req_rx.try_recv() {
                        Ok((m, r)) => batchers[m].push(r),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
                let mut released_any = false;
                for (m, b) in batchers.iter_mut().enumerate() {
                    let release = if open {
                        b.try_release(Instant::now())
                    } else {
                        let all = b.flush();
                        if all.is_empty() {
                            None
                        } else {
                            Some(all)
                        }
                    };
                    if let Some(batch) = release {
                        released_any = true;
                        // Flushes can exceed max_batch; split to
                        // respect the channel payload bound.
                        for chunk in batch.chunks(16 * 1024) {
                            let _ = batch_txs[next_worker % batch_txs.len()]
                                .send((m, chunk.to_vec()));
                            next_worker += 1;
                        }
                    }
                }
                if !released_any && open {
                    if batchers.iter().map(Batcher::pending).sum::<usize>() == 0 {
                        // Fully idle: block on the channel instead of
                        // spinning a core. 1 ms bounds the wait so a
                        // max-wait deadline armed by a race is still
                        // honored promptly.
                        match req_rx.recv_timeout(Duration::from_millis(1)) {
                            Ok((m, r)) => batchers[m].push(r),
                            Err(RecvTimeoutError::Timeout) => {}
                            Err(RecvTimeoutError::Disconnected) => open = false,
                        }
                    } else {
                        // A batch is pending its max-wait deadline;
                        // stay responsive.
                        std::thread::yield_now();
                    }
                }
            }
            drop(batch_txs); // close workers
            for h in worker_handles {
                let _ = h.join();
            }
        });

        Ok((
            Self { req_tx: Some(req_tx), metrics, dispatcher: Some(dispatcher) },
            resp_rx,
        ))
    }

    /// Submit a request to one model's lane (non-blocking).
    pub fn submit(&self, model: usize, req: InferRequest) -> crate::Result<()> {
        self.req_tx
            .as_ref()
            .ok_or_else(|| crate::Error::Coordinator("engine stopping".into()))?
            .send((model, req))
            .map_err(|_| crate::Error::Coordinator("engine stopped".into()))
    }

    /// Clone the raw request sender (sessions submit through this;
    /// the core's own copy still controls channel closure — dropping
    /// session clones never shuts the engine down, and
    /// [`EngineCore::shutdown`] invalidates them via the owner).
    /// Panics if called after shutdown.
    pub fn sender(&self) -> Sender<(usize, InferRequest)> {
        self.req_tx.as_ref().expect("engine core already shut down").clone()
    }

    /// Shared handle to the aggregate metrics (sessions snapshot it).
    pub fn metrics_handle(&self) -> Arc<Mutex<Metrics>> {
        Arc::clone(&self.metrics)
    }

    /// Snapshot aggregate metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Stop accepting requests, drain every lane, join all threads.
    pub fn shutdown(&mut self) -> Metrics {
        self.req_tx.take(); // close the request channel
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

impl Drop for EngineCore {
    fn drop(&mut self) {
        self.req_tx.take();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

/// Execute one batch on a backend and fan out responses. (Moved here
/// from `coordinator::server`, unchanged semantics: stack → infer →
/// per-request latency + response, one metrics record per batch.)
pub(crate) fn run_batch<B: InferBackend + ?Sized>(
    backend: &mut B,
    batch: Vec<InferRequest>,
    resp_tx: &Sender<Completion>,
    metrics: &Arc<Mutex<Metrics>>,
) -> crate::Result<()> {
    let n = batch.len();
    if n == 0 {
        return Ok(());
    }
    // Stack images into (N, C, H, W).
    let img_shape = batch[0].image.shape().to_vec();
    let mut stacked_shape = vec![n];
    stacked_shape.extend_from_slice(&img_shape);
    let mut data = Vec::with_capacity(batch.iter().map(|r| r.image.len()).sum());
    for r in &batch {
        if r.image.shape() != img_shape.as_slice() {
            return Err(crate::Error::Shape("heterogeneous image shapes in batch".into()));
        }
        data.extend_from_slice(r.image.data());
    }
    let images = Tensor::from_vec(&stacked_shape, data)?;
    let logits = backend.infer_batch(&images)?;
    if logits.len() != n {
        return Err(crate::Error::Coordinator(format!(
            "backend returned {} results for batch of {n}",
            logits.len()
        )));
    }
    let sim_cycles = backend.sim_cycles(n);
    let done = Instant::now();
    let mut latencies = Vec::with_capacity(n);
    for (req, lg) in batch.into_iter().zip(logits) {
        let latency_us = done.duration_since(req.enqueued).as_secs_f64() * 1e6;
        latencies.push(latency_us);
        let argmax = lg
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(i, _)| i)
            .unwrap_or(0);
        let _ = resp_tx.send(Completion::Done(InferResponse {
            id: req.id,
            logits: lg,
            argmax,
            latency_us,
            sim_cycles: sim_cycles / n as u64,
            batch_size: n,
        }));
    }
    let mut m = metrics.lock().unwrap();
    m.record_batch(n, &latencies, sim_cycles);
    if let Some((rows, windows, total)) = backend.skip_counters() {
        m.set_skip_counters(rows, windows, total);
    }
    if let Some((decodes, adds)) = backend.sac_counters() {
        m.set_sac_counters(decodes, adds);
    }
    Ok(())
}
