//! The engine's model registry: named networks, each compiled exactly
//! once at [`EngineBuilder::build`](super::EngineBuilder::build) time
//! into the backend the engine's [`BackendKind`](super::BackendKind)
//! selects.

use std::path::Path;
use std::sync::Arc;

use crate::config::{AccelConfig, CalibConfig};
use crate::coordinator::backend::{InferBackend, PjrtBackend, SacBackend};
use crate::model::{ConvLayer, LoadedWeights, Network, TopoOp};
use crate::plan::{tune, CompiledNetwork, Kernel, Walk};
use crate::sim::{sample::samples_from_loaded, simulate_network_with_samples, tetris::TetrisSim};

use super::serve::BackendFactory;

/// Index of a registered model inside its engine — stable for the
/// engine's lifetime, resolvable from the name via
/// [`Engine::model_id`](super::Engine::model_id).
pub type ModelId = usize;

/// One model registration: a display name plus the declared network
/// and its weight set. Compilation happens once, at engine build.
pub struct ModelSpec {
    pub name: String,
    pub network: Network,
    pub weights: LoadedWeights,
}

impl ModelSpec {
    pub fn new(name: impl Into<String>, network: Network, weights: LoadedWeights) -> Self {
        Self { name: name.into(), network, weights }
    }
}

/// Compile-time product of one registration: what the engine exposes
/// for introspection (the shared plan, simulated per-image cost) and
/// what sessions validate submissions against.
pub struct ModelMeta {
    pub(crate) name: String,
    pub(crate) backend: &'static str,
    /// The one shared compiled plan (SAC models; PJRT executables are
    /// thread-pinned and live inside the workers instead).
    pub(crate) plan: Option<Arc<CompiledNetwork>>,
    pub(crate) cycles_per_image: u64,
    /// Simulated Tetris cycles per image for each **executable** FC
    /// head (name, cycles), schedule order — empty when the model
    /// serves a conv trunk only. Folded into `cycles_per_image`.
    pub(crate) head_cycles: Vec<(String, u64)>,
    /// The walk the plan is pinned to (`plan.walk_hint`): `Some` when
    /// the caller pinned one or the memory budget demanded the
    /// pipelined walk at compile time, `None` when the executor's
    /// batch-vs-workers policy decides per call (and always `None` for
    /// PJRT lanes, which have no plan).
    pub(crate) walk: Option<Walk>,
    /// Input channel count submissions are validated against.
    pub(crate) in_c: Option<usize>,
    /// Declared input spatial size submissions are validated against.
    /// Serving is fixed-shape per model (the executor itself accepts
    /// other extents, but mixed shapes inside one dynamic batch would
    /// poison co-batched requests — so sessions reject them up
    /// front).
    pub(crate) in_hw: Option<usize>,
}

impl ModelMeta {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Which backend serves this model (`"sac-rust"` / `"pjrt-xla"`).
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// The shared compiled plan, for SAC models.
    pub fn plan(&self) -> Option<&Arc<CompiledNetwork>> {
        self.plan.as_ref()
    }

    /// Simulated Tetris cycles per image (conv trunk + executable FC
    /// heads).
    pub fn cycles_per_image(&self) -> u64 {
        self.cycles_per_image
    }

    /// Per-head simulated cycles for the model's executable FC heads
    /// (empty for conv-trunk models) — the serving-side counterpart of
    /// `tetris simulate --include-fc`'s per-head rows.
    pub fn head_cycles(&self) -> &[(String, u64)] {
        &self.head_cycles
    }

    /// The walk this model's plan is pinned to. `Some(Walk::Pipelined)`
    /// means the registered memory budget could not hold even the
    /// per-segment streaming walk's peak, so serving chains the rings
    /// across segment boundaries ([`Walk::Pipelined`]) for
    /// depth-independent peak memory. `None` leaves the executor's
    /// batch-vs-workers default policy in charge.
    pub fn walk(&self) -> Option<Walk> {
        self.walk
    }

    /// Input channel count submissions are validated against (`None`
    /// when the model declares no entry conv — nothing to validate).
    pub fn input_channels(&self) -> Option<usize> {
        self.in_c
    }

    /// Declared input spatial size submissions are validated against.
    pub fn input_hw(&self) -> Option<usize> {
        self.in_hw
    }
}

/// First scheduled conv's declared input shape — (channels, spatial
/// size) submissions must match.
fn entry_shape(net: &Network) -> Option<(usize, usize)> {
    fn find(ops: &[TopoOp], net: &Network) -> Option<(usize, usize)> {
        for op in ops {
            match op {
                TopoOp::Conv(i) => return net.layers.get(*i).map(|l| (l.in_c, l.in_hw)),
                TopoOp::Branch(arms) => {
                    if let Some(s) = arms.iter().find_map(|a| find(a, net)) {
                        return Some(s);
                    }
                }
                _ => {}
            }
        }
        None
    }
    find(&net.schedule, net)
}

/// Compile one SAC registration: knead every lane once, pick the fused
/// tile height from the resolved memory budget (unless overridden),
/// pre-simulate the per-image accelerator cost, and return the lane
/// metadata plus a factory whose per-worker "construction" is an
/// `Arc`-sharing clone — W workers, one compile.
///
/// Walk/tile selection routes through the schedule auto-tuner
/// (`plan::tune`, memoized per plan fingerprint × budget × workers):
/// an explicit `walk` pins the plan to that dataflow and sizes the
/// tile with the matching estimator; an explicit `tile_rows` is
/// honored verbatim. With neither pin (and `auto_tune` on), the tuner
/// searches the walk × tile space — including the budget-demanded
/// [`Walk::Pipelined`] fallover for deep trunks whose per-segment
/// peaks exceed the budget — and warns once when not even the 1-row
/// floor fits. `auto_tune` off reverts to plain budget-ladder sizing.
#[allow(clippy::too_many_arguments)]
pub(crate) fn compile_sac(
    spec: ModelSpec,
    ks: usize,
    budget_bytes: u64,
    tile_rows: Option<usize>,
    workers: usize,
    walk: Option<Walk>,
    auto_tune: bool,
    skip_zero_activations: bool,
    kernel: Option<Kernel>,
) -> crate::Result<(ModelMeta, BackendFactory)> {
    let ModelSpec { name, network, weights } = spec;
    let mode = weights.mode;
    let mut plan = CompiledNetwork::compile(&network, &weights, ks, mode)?;
    let tuned = tune::tune_pinned(&plan, budget_bytes, workers, walk, tile_rows, auto_tune);
    tuned.apply(&mut plan);
    // A scheduling default like walk_hint/tile_rows: callers of
    // `execute` get the skip lane without threading ExecOpts, and an
    // explicit ExecOpts::skip_zero_activations still overrides.
    plan.skip_zero_activations = skip_zero_activations;
    // Same contract for the conv kernel: a builder pin replaces the
    // compiled default (Decoded); ExecOpts::kernel still overrides.
    if let Some(k) = kernel {
        plan.kernel = k;
    }
    // Timing from the registered weights' bit statistics, so serving
    // metrics report the paper's accelerator rather than the host.
    let cfg = AccelConfig { ks, mode, ..AccelConfig::default() };
    let calib = CalibConfig::default();
    let samples = samples_from_loaded(&network, &weights)?;
    let sim = simulate_network_with_samples(&TetrisSim, &network, &samples, &cfg, &calib);
    let trunk_cycles = sim.total_cycles();

    // Every FC head the plan actually EXECUTES (`fc_heads` — declared
    // stacks and the implicit appended `fc` alike) simulates as its
    // 1×1-conv equivalent, the same lowering `Network::
    // fc_as_conv_layers` / `tetris simulate --include-fc` use for
    // declared specs, one head per row so serving can report per-head
    // cost. Declaration-only heads cost nothing because the plan
    // never streams them. Keying off the compiled heads (rather than
    // the declared specs) keeps `cycles_per_image` head-inclusive for
    // every model whose plan serves logits.
    let mut head_cycles: Vec<(String, u64)> = Vec::new();
    for head in plan.fc_heads() {
        let head_net = Network {
            name: network.name.clone(),
            layers: vec![ConvLayer {
                name: head.name.clone(),
                in_c: head.feat_dim,
                out_c: head.classes,
                k: 1,
                stride: 1,
                pad: 0,
                in_hw: 1,
            }],
            schedule: Vec::new(),
        };
        let head_samples = samples_from_loaded(&head_net, &weights)?;
        let head_sim =
            simulate_network_with_samples(&TetrisSim, &head_net, &head_samples, &cfg, &calib);
        head_cycles.push((head.name.clone(), head_sim.total_cycles()));
    }
    let cycles = trunk_cycles + head_cycles.iter().map(|(_, c)| c).sum::<u64>();

    let plan = Arc::new(plan);
    let entry = entry_shape(&network);
    let meta = ModelMeta {
        name,
        backend: "sac-rust",
        plan: Some(Arc::clone(&plan)),
        cycles_per_image: cycles,
        head_cycles,
        walk: plan.walk_hint,
        in_c: entry.map(|(c, _)| c),
        in_hw: entry.map(|(_, hw)| hw),
    };
    let prototype = SacBackend::from_parts(plan, cycles);
    let factory: BackendFactory =
        Arc::new(move |_w| Ok(Box::new(prototype.clone()) as Box<dyn InferBackend>));
    Ok((meta, factory))
}

/// Build the PJRT lane for the AOT golden model: probe once on the
/// calling thread (fail fast — without the `xla` + `xla-vendored`
/// features, or without artifacts, this is where the error surfaces),
/// then hand back a factory that compiles a thread-pinned executable
/// per worker.
pub(crate) fn pjrt_lane(artifacts: &Path) -> crate::Result<(ModelMeta, BackendFactory)> {
    let probe = PjrtBackend::from_artifacts(artifacts)?;
    let cycles = probe.sim_cycles(1);
    let meta = ModelMeta {
        name: "golden".into(),
        backend: "pjrt-xla",
        plan: None,
        cycles_per_image: cycles,
        head_cycles: Vec::new(),
        walk: None,
        in_c: Some(probe.input_channels()),
        in_hw: Some(probe.input_hw()),
    };
    drop(probe);
    let dir = artifacts.to_path_buf();
    let factory: BackendFactory = Arc::new(move |_w| {
        PjrtBackend::from_artifacts_with_cycles(&dir, cycles)
            .map(|b| Box::new(b) as Box<dyn InferBackend>)
    });
    Ok((meta, factory))
}
