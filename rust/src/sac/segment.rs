//! Segment registers S0..S(B-1) with their two-input segment adders.

/// The per-bit accumulation state of one SAC unit.
///
/// Register width: in hardware these are sized so that `lanes × max
/// activation` never overflows (the paper's design consumes a bounded
/// number of pairs between drains); we use i64 and *assert* the hardware
/// bound instead of silently wrapping.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentRegisters {
    regs: Vec<i64>,
    /// Count of accumulations since the last drain (hardware-bound check).
    adds: u64,
}

impl SegmentRegisters {
    pub fn new(bits: usize) -> Self {
        Self { regs: vec![0; bits], adds: 0 }
    }

    pub fn bits(&self) -> usize {
        self.regs.len()
    }

    /// Segment adder: accumulate a (sign-adjusted) activation into S_b.
    #[inline]
    pub fn accumulate(&mut self, b: usize, value: i64) {
        self.regs[b] += value;
        self.adds += 1;
    }

    /// Read segment `b`.
    #[inline]
    pub fn get(&self, b: usize) -> i64 {
        self.regs[b]
    }

    pub fn values(&self) -> &[i64] {
        &self.regs
    }

    /// Number of accumulate operations performed (energy accounting).
    pub fn add_count(&self) -> u64 {
        self.adds
    }

    /// Drain for the rear adder tree: return values and reset ("pass
    /// control signals inform the multiplexer to pass each segment
    /// value to the rear adder tree", §III.C.2).
    pub fn drain(&mut self) -> Vec<i64> {
        let out = self.regs.clone();
        self.reset();
        out
    }

    /// Allocation-free [`SegmentRegisters::drain`]: copy the segment
    /// values into a caller-owned buffer (which must hold exactly
    /// [`SegmentRegisters::bits`] values) and reset. The hot-path form
    /// — `drain` clones a fresh `Vec` per call, which on the serving
    /// path would mean one allocation per output pixel per filter.
    pub fn drain_into(&mut self, dst: &mut [i64]) {
        dst.copy_from_slice(&self.regs);
        self.reset();
    }

    /// Zero all registers without allocating (hot-path drain).
    pub fn reset(&mut self) {
        self.regs.iter_mut().for_each(|r| *r = 0);
        self.adds = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_and_drain() {
        let mut s = SegmentRegisters::new(16);
        s.accumulate(0, 5);
        s.accumulate(0, 7);
        s.accumulate(15, -3);
        assert_eq!(s.get(0), 12);
        assert_eq!(s.get(15), -3);
        assert_eq!(s.add_count(), 3);
        let drained = s.drain();
        assert_eq!(drained[0], 12);
        assert_eq!(drained[15], -3);
        assert!(s.values().iter().all(|&v| v == 0));
        assert_eq!(s.add_count(), 0);
    }

    #[test]
    fn drain_into_matches_drain() {
        let mut a = SegmentRegisters::new(16);
        let mut b = SegmentRegisters::new(16);
        for (bit, v) in [(0usize, 5i64), (0, 7), (3, -2), (15, -3)] {
            a.accumulate(bit, v);
            b.accumulate(bit, v);
        }
        let want = a.drain();
        let mut got = vec![0i64; 16];
        b.drain_into(&mut got);
        assert_eq!(got, want);
        assert!(b.values().iter().all(|&v| v == 0));
        assert_eq!(b.add_count(), 0);
        // Reusable: a stale buffer is fully overwritten.
        b.accumulate(1, 9);
        b.drain_into(&mut got);
        assert_eq!(got[0], 0);
        assert_eq!(got[1], 9);
    }

    #[test]
    #[should_panic]
    fn out_of_range_segment_panics() {
        let mut s = SegmentRegisters::new(8);
        s.accumulate(8, 1);
    }
}
