//! A SAC unit: splitter array + segment adders + rear adder tree
//! (Fig 5), functional level.
//!
//! Processes whole lanes and produces bit-exact partial sums along with
//! activity counters the energy model consumes. Cycle-accurate behaviour
//! (throttle buffer occupancy, pass-mark synchronization) lives in
//! `sim::tetris` — this type answers "what value, how many operations".

use super::segment::SegmentRegisters;
use super::splitter::split_kneaded;
use crate::config::Mode;
use crate::kneading::{knead_lane, KneadedLane, Lane};

/// Activity counters for one lane's worth of SAC processing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SacActivity {
    /// Kneaded weights consumed.
    pub kneaded_weights: u64,
    /// Slot decodes performed by splitters (comparator+mux activations).
    pub slot_decodes: u64,
    /// Segment-adder accumulations.
    pub segment_adds: u64,
    /// Rear-adder-tree invocations (one per lane drain).
    pub tree_drains: u64,
}

/// One SAC unit.
#[derive(Debug, Clone)]
pub struct SacUnit {
    mode: Mode,
    segs: SegmentRegisters,
    activity: SacActivity,
    /// Drain buffer reused across lanes (`drain_into`) — a unit that
    /// processes a lane per output pixel must not allocate per drain.
    scratch: Vec<i64>,
}

impl SacUnit {
    pub fn new(mode: Mode) -> Self {
        Self {
            mode,
            segs: SegmentRegisters::new(mode.weight_bits()),
            activity: SacActivity::default(),
            scratch: vec![0; mode.weight_bits()],
        }
    }

    pub fn mode(&self) -> Mode {
        self.mode
    }

    pub fn activity(&self) -> SacActivity {
        self.activity
    }

    /// Process an already-kneaded lane against its activations; returns
    /// the final partial sum (rear adder tree output).
    pub fn process_kneaded(&mut self, kneaded: &KneadedLane, lane: &Lane) -> i64 {
        assert_eq!(
            kneaded.bits,
            self.mode.weight_bits(),
            "kneaded lane width does not match unit mode"
        );
        for (g, group) in kneaded.groups.iter().enumerate() {
            let acts = lane.group_acts(g, kneaded.ks);
            let before = self.segs.add_count();
            let decodes = split_kneaded(group, acts, &mut self.segs);
            self.activity.kneaded_weights += group.len() as u64;
            self.activity.slot_decodes += decodes;
            self.activity.segment_adds += self.segs.add_count() - before;
        }
        self.activity.tree_drains += 1;
        self.segs.drain_into(&mut self.scratch);
        super::adder_tree::rear_adder_tree(&self.scratch)
    }

    /// Knead + process in one step.
    pub fn process_lane(&mut self, lane: &Lane, ks: usize) -> i64 {
        let kneaded = knead_lane(lane, ks, self.mode);
        self.process_kneaded(&kneaded, lane)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn random_lane(r: &mut Rng, bits: u32, max_len: usize) -> Lane {
        let len = 1 + r.below(max_len as u64) as usize;
        Lane::random(
            len,
            r,
            |r| prop::gen::weight(r, bits),
            |r| prop::gen::activation(r),
        )
    }

    /// DESIGN.md invariant I2/I3: kneaded SAC ≡ MAC, any KS, both modes.
    #[test]
    fn kneaded_sac_equals_mac_all_modes_and_strides() {
        for mode in [Mode::Fp16, Mode::Int8] {
            let bits = mode.weight_bits() as u32;
            for ks in [2, 3, 10, 16, 32] {
                prop::run_with(
                    crate::util::prop::PropConfig { cases: 128, seed: 0xABCD ^ ks as u64 },
                    "SAC == MAC",
                    |r: &mut Rng| random_lane(r, bits, 100),
                    |lane| {
                        let mut unit = SacUnit::new(mode);
                        let got = unit.process_lane(lane, ks);
                        let want = lane.mac_reference();
                        if got == want {
                            Ok(())
                        } else {
                            Err(format!("{mode} ks={ks}: SAC {got} != MAC {want}"))
                        }
                    },
                );
            }
        }
    }

    #[test]
    fn activity_counters_accumulate() {
        let mut rng = Rng::new(5);
        let lane = random_lane(&mut rng, 16, 64);
        let mut unit = SacUnit::new(Mode::Fp16);
        unit.process_lane(&lane, 16);
        let a = unit.activity();
        assert!(a.kneaded_weights > 0);
        assert_eq!(a.slot_decodes, a.kneaded_weights * 16);
        assert_eq!(a.tree_drains, 1);
        // Segment adds == total essential bits in the lane.
        let essential: u64 = lane
            .weights
            .iter()
            .map(|&w| crate::quant::essential_bits(w, 16) as u64)
            .sum();
        assert_eq!(a.segment_adds, essential);
    }

    #[test]
    fn unit_is_reusable_across_lanes() {
        let mut rng = Rng::new(9);
        let mut unit = SacUnit::new(Mode::Fp16);
        for _ in 0..10 {
            let lane = random_lane(&mut rng, 16, 40);
            assert_eq!(unit.process_lane(&lane, 16), lane.mac_reference());
        }
        assert_eq!(unit.activity().tree_drains, 10);
    }

    #[test]
    #[should_panic(expected = "width does not match")]
    fn mode_mismatch_panics() {
        let lane = Lane::new(vec![1, 2], vec![3, 4]);
        let kneaded = knead_lane(&lane, 16, Mode::Fp16);
        let mut unit = SacUnit::new(Mode::Int8);
        unit.process_kneaded(&kneaded, &lane);
    }
}
