//! The splitter (Fig 6): decode a (kneaded) weight's bit slots and route
//! activations to segment adders.

use super::segment::SegmentRegisters;
use crate::kneading::KneadedGroup;
use crate::quant::{QAct, QWeight};

/// Pair-wise SAC (Fig 4): split a *plain* weight — each essential bit of
/// `w` routes `a` (sign-adjusted) into its segment. Conceptual mode; the
/// accelerator uses [`split_kneaded`].
pub fn split_pairwise(w: QWeight, a: QAct, segs: &mut SegmentRegisters) {
    let sign = if w < 0 { -1i64 } else { 1i64 };
    let mut mag = w.unsigned_abs();
    let bits = segs.bits();
    if bits < 32 {
        mag &= (1u32 << bits) - 1;
    }
    while mag != 0 {
        let b = mag.trailing_zeros() as usize;
        segs.accumulate(b, sign * a as i64);
        mag &= mag - 1;
    }
}

/// Kneaded-weight SAC over one group: for each kneaded weight, decode
/// every occupied slot `<b, p>` and route activation `acts[p]`
/// (sign-adjusted by the group's sign mask) to segment adder `b`.
///
/// `acts` is the KS-wide activation window of this group ("the splitter
/// only needs to fetch the target activation in the throttle buffer when
/// necessary", §III.C.2).
///
/// Returns the number of slot decodes performed (splitter activity, for
/// energy accounting).
pub fn split_kneaded(group: &KneadedGroup, acts: &[QAct], segs: &mut SegmentRegisters) -> u64 {
    debug_assert!(
        acts.len() >= group.source_len,
        "activation window shorter than group"
    );
    let mut decodes = 0u64;
    for kw in &group.kneaded {
        // The comparator array examines every slot in hardware (Fig 6);
        // in software we walk only the occupied-slot mask (§Perf) and
        // charge the full decode count for the energy model.
        decodes += kw.slots().len() as u64;
        let mut mask = kw.occupied_mask();
        while mask != 0 {
            let b = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            let slot = kw.pointer(b);
            let a = acts[slot as usize] as i64;
            segs.accumulate(b, group.sign_of(slot) * a);
        }
    }
    decodes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Mode;
    use crate::kneading::knead_group;
    use crate::sac::rear_adder_tree;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn pairwise_split_equals_multiply() {
        prop::run(
            "pairwise SAC == a*w",
            |r: &mut Rng| (prop::gen::weight(r, 16), prop::gen::activation(r)),
            |&(w, a)| {
                let mut segs = SegmentRegisters::new(16);
                split_pairwise(w, a, &mut segs);
                let got = rear_adder_tree(segs.values());
                let want = w as i64 * a as i64;
                if got == want {
                    Ok(())
                } else {
                    Err(format!("got {got}, want {want}"))
                }
            },
        );
    }

    #[test]
    fn kneaded_split_references_right_activations() {
        // w0 = 0b01 (bit 0), w1 = 0b10 (bit 1), acts 100/200:
        // segment 0 must get 100 (from w0), segment 1 must get 200 (w1).
        let g = knead_group(&[0b01, 0b10], Mode::Fp16);
        assert_eq!(g.len(), 1);
        let mut segs = SegmentRegisters::new(16);
        split_kneaded(&g, &[100, 200], &mut segs);
        assert_eq!(segs.get(0), 100);
        assert_eq!(segs.get(1), 200);
        assert_eq!(rear_adder_tree(segs.values()), 100 + 2 * 200);
    }

    #[test]
    fn signs_ride_with_activations() {
        let g = knead_group(&[-0b1, 0b1], Mode::Fp16);
        let mut segs = SegmentRegisters::new(16);
        split_kneaded(&g, &[10, 30], &mut segs);
        assert_eq!(segs.get(0), -10 + 30);
    }

    #[test]
    fn decode_count_is_kneaded_times_bits() {
        let g = knead_group(&[0b111, 0b1, 0b1], Mode::Fp16);
        let mut segs = SegmentRegisters::new(16);
        let decodes = split_kneaded(&g, &[1, 1, 1], &mut segs);
        assert_eq!(decodes, g.len() as u64 * 16);
    }
}
