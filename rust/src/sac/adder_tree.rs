//! The rear adder tree: the single final shift-and-add (§III.C).
//!
//! `Σ_b 2^b · S_b`, evaluated as a balanced binary tree in hardware
//! (log2(16) = 4 levels). Functionally it is one weighted reduction; the
//! tree structure only matters for the latency/energy models.

/// Final partial sum from drained segment values.
pub fn rear_adder_tree(segments: &[i64]) -> i64 {
    segments
        .iter()
        .enumerate()
        .map(|(b, &s)| s << b)
        .sum()
}

/// Tree-structured evaluation (pairwise reduction) — used by tests to
/// show associativity holds and by the latency model to count levels.
pub fn rear_adder_tree_levels(segments: &[i64]) -> (i64, u32) {
    let mut vals: Vec<i64> = segments.iter().enumerate().map(|(b, &s)| s << b).collect();
    let mut levels = 0;
    while vals.len() > 1 {
        vals = vals.chunks(2).map(|c| c.iter().sum()).collect();
        levels += 1;
    }
    (vals.first().copied().unwrap_or(0), levels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn weighted_sum_simple() {
        let mut segs = vec![0i64; 16];
        segs[0] = 3;
        segs[4] = 1;
        assert_eq!(rear_adder_tree(&segs), 3 + 16);
    }

    #[test]
    fn tree_matches_flat_sum_and_has_log_levels() {
        prop::run(
            "tree reduction == flat reduction",
            |r: &mut Rng| {
                (0..16).map(|_| r.range_i64(-1 << 40, 1 << 40)).collect::<Vec<i64>>()
            },
            |segs| {
                let flat = rear_adder_tree(segs);
                let (tree, levels) = rear_adder_tree_levels(segs);
                if flat != tree {
                    return Err(format!("flat {flat} != tree {tree}"));
                }
                if levels != 4 {
                    return Err(format!("16 segments must take 4 levels, got {levels}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(rear_adder_tree(&[]), 0);
        assert_eq!(rear_adder_tree(&[7]), 7);
        assert_eq!(rear_adder_tree_levels(&[]).0, 0);
    }

    #[test]
    fn int8_width_shifts() {
        let mut segs = vec![0i64; 8];
        segs[7] = 2;
        assert_eq!(rear_adder_tree(&segs), 2 << 7);
    }
}
