//! SAC — split-and-accumulate (§III.C), the paper's replacement for MAC.
//!
//! A SAC unit holds per-bit-position *segment registers* S0..S15. The
//! *splitter* walks a (kneaded) weight's bit slots and, for each
//! essential bit at position `b`, routes the referenced activation
//! (sign-adjusted) to segment adder `b`. Only after the whole lane is
//! consumed does the *rear adder tree* perform the single shift-and-add
//! `Σ_b 2^b · S_b` — off the critical path, once per partial sum.
//!
//! Everything in this module is *functional* (bit-exact values);
//! cycle/energy accounting lives in [`crate::sim`].

mod adder_tree;
mod segment;
mod splitter;
mod unit;

pub use adder_tree::{rear_adder_tree, rear_adder_tree_levels};
pub use segment::SegmentRegisters;
pub use splitter::{split_kneaded, split_pairwise};
pub use unit::SacUnit;
