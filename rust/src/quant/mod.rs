//! Fixed-point quantization and bit-level utilities (§II of the paper).
//!
//! The paper quantizes fp32 Caffe weights to 16-bit fixed point ("fp16"
//! in the paper's vocabulary — *fixed* point, not IEEE half) and int8.
//! All SAC/kneading machinery operates sign-magnitude: the sign rides
//! with the activation dispatch (the splitter negates the routed
//! activation), while the magnitude's bits are what kneading packs.

mod bits;
mod fixed;
pub mod stats;

pub use bits::{bit_is_set, essential_bits, popcount_per_position, BitIter};
pub use fixed::{dequantize, quantize_q, QFormat};

use crate::config::Mode;

/// A quantized weight: signed integer whose magnitude fits the mode's
/// bit width (`|w| < 2^(bits-1)`, one headroom bit reserved so Q1.(B-1)
/// magnitudes never alias the sign).
pub type QWeight = i32;

/// A quantized activation (post-ReLU ⇒ non-negative in real layers, but
/// all machinery accepts signed values so FC / pre-activation paths work).
pub type QAct = i32;

/// Assert a weight is representable in `mode`; used at lane-construction
/// time (debug) and by the property tests.
#[inline]
pub fn fits_mode(w: QWeight, mode: Mode) -> bool {
    w.unsigned_abs() < mode.magnitude_bound() as u32
}

/// Rounding right shift — mirror of python `_requantize`. Shifting by
/// zero is the identity: the naive `1 << (frac_bits - 1)` rounding bias
/// underflows (debug panic) when `frac_bits == 0`, so that case is
/// guarded explicitly.
#[inline]
pub fn requantize(acc: i32, frac_bits: u32) -> i32 {
    if frac_bits == 0 {
        return acc;
    }
    (acc + (1 << (frac_bits - 1))) >> frac_bits
}

/// The paper's Eq. (1): decompose one multiplication into shift-and-adds
/// over the weight's essential bits. Reference implementation used by
/// tests to cross-check the SAC units.
pub fn shift_add_mul(a: QAct, w: QWeight) -> i64 {
    let sign = if w < 0 { -1i64 } else { 1i64 };
    let mag = w.unsigned_abs();
    let mut acc = 0i64;
    for b in 0..32 {
        if mag & (1 << b) != 0 {
            acc += (a as i64) << b;
        }
    }
    sign * acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn shift_add_mul_equals_multiplication() {
        prop::run(
            "shift_add_mul == a*w",
            |r: &mut Rng| (prop::gen::activation(r), prop::gen::weight(r, 16)),
            |&(a, w)| {
                let got = shift_add_mul(a, w);
                let want = a as i64 * w as i64;
                if got == want {
                    Ok(())
                } else {
                    Err(format!("got {got}, want {want}"))
                }
            },
        );
    }

    #[test]
    fn requantize_zero_shift_is_identity() {
        // Regression: `1 << (frac_bits - 1)` underflowed for frac 0.
        for v in [0, 1, -1, 255, -255, i32::MAX, i32::MIN] {
            assert_eq!(requantize(v, 0), v);
        }
    }

    #[test]
    fn fits_mode_boundaries() {
        assert!(fits_mode(0x7FFE, Mode::Fp16));
        assert!(!fits_mode(0x8000, Mode::Fp16));
        assert!(fits_mode(-0x7FFF, Mode::Fp16));
        assert!(fits_mode(127, Mode::Int8));
        assert!(!fits_mode(128, Mode::Int8));
    }
}
