//! Bit-level distribution measurement over weight populations.
//!
//! Produces the quantities behind the paper's Table 1 (zero-value and
//! zero-bit fractions) and Figure 2 (essential-bit density per bit
//! position).

use super::QWeight;
use crate::config::Mode;

/// Aggregated bit statistics for a weight population.
#[derive(Debug, Clone, PartialEq)]
pub struct BitStats {
    /// Total weights observed.
    pub total: u64,
    /// Weights whose quantized value is exactly zero.
    pub zero_weights: u64,
    /// Per-bit-position essential (1) counts, length = mode bits.
    pub essential_per_bit: Vec<u64>,
    /// Bit width used.
    pub bits: u32,
}

impl BitStats {
    pub fn new(mode: Mode) -> Self {
        let bits = mode.weight_bits() as u32;
        Self { total: 0, zero_weights: 0, essential_per_bit: vec![0; bits as usize], bits }
    }

    /// Accumulate one weight.
    #[inline]
    pub fn add(&mut self, w: QWeight) {
        self.total += 1;
        if w == 0 {
            self.zero_weights += 1;
        }
        let mut mag = w.unsigned_abs();
        if self.bits < 32 {
            mag &= (1u32 << self.bits) - 1;
        }
        while mag != 0 {
            let b = mag.trailing_zeros();
            self.essential_per_bit[b as usize] += 1;
            mag &= mag - 1;
        }
    }

    pub fn add_all(&mut self, ws: &[QWeight]) {
        for &w in ws {
            self.add(w);
        }
    }

    /// Merge two populations (parallel accumulation).
    pub fn merge(&mut self, other: &BitStats) {
        assert_eq!(self.bits, other.bits, "mode mismatch in BitStats::merge");
        self.total += other.total;
        self.zero_weights += other.zero_weights;
        for (a, b) in self.essential_per_bit.iter_mut().zip(&other.essential_per_bit) {
            *a += b;
        }
    }

    /// Table 1 column: fraction of exactly-zero weights.
    pub fn zero_weight_fraction(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.zero_weights as f64 / self.total as f64
    }

    /// Table 1 column: fraction of zero bits over all (weight, position)
    /// pairs.
    pub fn zero_bit_fraction(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let total_bits = self.total * self.bits as u64;
        let essential: u64 = self.essential_per_bit.iter().sum();
        1.0 - essential as f64 / total_bits as f64
    }

    /// Figure 2 series: essential-bit density at each bit position.
    pub fn essential_density_per_bit(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.bits as usize];
        }
        self.essential_per_bit.iter().map(|&c| c as f64 / self.total as f64).collect()
    }

    /// Mean essential bits per weight — the quantity PRA's serial cycles
    /// track.
    pub fn mean_essential_bits(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.essential_per_bit.iter().sum::<u64>() as f64 / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_zero_weights_and_bits() {
        let mut s = BitStats::new(Mode::Fp16);
        s.add_all(&[0, 0b1, -0b11, 0]);
        assert_eq!(s.total, 4);
        assert_eq!(s.zero_weights, 2);
        assert_eq!(s.zero_weight_fraction(), 0.5);
        // essential bits: 1 + 2 = 3 of 4*16 = 64 → zero-bit frac 61/64
        assert!((s.zero_bit_fraction() - 61.0 / 64.0).abs() < 1e-12);
        assert_eq!(s.essential_per_bit[0], 2);
        assert_eq!(s.essential_per_bit[1], 1);
    }

    #[test]
    fn density_per_bit() {
        let mut s = BitStats::new(Mode::Int8);
        s.add_all(&[0b1, 0b1, 0b10, 0b11]);
        let d = s.essential_density_per_bit();
        assert_eq!(d.len(), 8);
        assert!((d[0] - 0.75).abs() < 1e-12);
        assert!((d[1] - 0.5).abs() < 1e-12);
        assert_eq!(d[7], 0.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let ws: Vec<i32> = (0..100).map(|i| (i * 37) % 256 - 128).collect();
        let mut all = BitStats::new(Mode::Fp16);
        all.add_all(&ws);
        let mut a = BitStats::new(Mode::Fp16);
        let mut b = BitStats::new(Mode::Fp16);
        a.add_all(&ws[..50]);
        b.add_all(&ws[50..]);
        a.merge(&b);
        assert_eq!(a, all);
    }

    #[test]
    fn mean_essential_bits_simple() {
        let mut s = BitStats::new(Mode::Fp16);
        s.add_all(&[0b111, 0b1]);
        assert!((s.mean_essential_bits() - 2.0).abs() < 1e-12);
    }
}
