//! Q-format fixed-point conversion.
//!
//! Conv weights live in roughly [-1, 1], so fp16 weights use Q1.15
//! (15 fractional bits) and int8 weights Q1.7 — matching the paper's
//! "quantize the initial floating point 32 weights into fixed point 16
//! and integer 8 precision" (§IV).

use crate::config::Mode;

/// A fixed-point format: `frac_bits` fractional bits within the mode's
/// magnitude budget.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    pub frac_bits: u32,
    pub mode: Mode,
}

impl QFormat {
    /// The formats the paper evaluates.
    pub fn for_mode(mode: Mode) -> Self {
        match mode {
            Mode::Fp16 => QFormat { frac_bits: 15, mode },
            Mode::Int8 => QFormat { frac_bits: 7, mode },
        }
    }

    /// Scale factor 2^frac_bits.
    pub fn scale(&self) -> f64 {
        (1u64 << self.frac_bits) as f64
    }

    /// Largest representable magnitude in value space.
    pub fn max_value(&self) -> f64 {
        (self.mode.magnitude_bound() - 1) as f64 / self.scale()
    }
}

/// Quantize an fp32 value: round-to-nearest-even, saturate.
pub fn quantize_q(x: f32, fmt: QFormat) -> i32 {
    let scaled = (x as f64) * fmt.scale();
    let rounded = round_half_even(scaled);
    let bound = (fmt.mode.magnitude_bound() - 1) as f64;
    rounded.clamp(-bound, bound) as i32
}

/// Back to value space.
pub fn dequantize(q: i32, fmt: QFormat) -> f64 {
    q as f64 / fmt.scale()
}

fn round_half_even(x: f64) -> f64 {
    let floor = x.floor();
    let diff = x - floor;
    if diff > 0.5 {
        floor + 1.0
    } else if diff < 0.5 {
        floor
    } else if (floor as i64) % 2 == 0 {
        floor
    } else {
        floor + 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn quantize_zero_and_signs() {
        let f = QFormat::for_mode(Mode::Fp16);
        assert_eq!(quantize_q(0.0, f), 0);
        assert!(quantize_q(0.5, f) > 0);
        assert!(quantize_q(-0.5, f) < 0);
        assert_eq!(quantize_q(0.5, f), 1 << 14);
    }

    #[test]
    fn saturation_at_bounds() {
        for mode in [Mode::Fp16, Mode::Int8] {
            let f = QFormat::for_mode(mode);
            assert_eq!(quantize_q(10.0, f), mode.magnitude_bound() - 1);
            assert_eq!(quantize_q(-10.0, f), -(mode.magnitude_bound() - 1));
        }
    }

    #[test]
    fn round_half_even_ties() {
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(3.5), 4.0);
        assert_eq!(round_half_even(-2.5), -2.0);
        assert_eq!(round_half_even(2.4), 2.0);
        assert_eq!(round_half_even(2.6), 3.0);
    }

    #[test]
    fn quantization_error_bounded_by_half_ulp() {
        for mode in [Mode::Fp16, Mode::Int8] {
            let fmt = QFormat::for_mode(mode);
            prop::run(
                "quant error ≤ 0.5 ulp",
                |r: &mut Rng| (r.f64() * 1.9 - 0.95) as f32,
                |&x| {
                    if (x as f64).abs() > fmt.max_value() {
                        return Ok(()); // saturation region
                    }
                    let q = quantize_q(x, fmt);
                    let err = (dequantize(q, fmt) - x as f64).abs();
                    let half_ulp = 0.5 / fmt.scale() + 1e-12;
                    if err <= half_ulp {
                        Ok(())
                    } else {
                        Err(format!("err {err} > half ulp {half_ulp}"))
                    }
                },
            );
        }
    }

    #[test]
    fn roundtrip_is_identity_on_grid() {
        let fmt = QFormat::for_mode(Mode::Int8);
        for q in -127..=127 {
            assert_eq!(quantize_q(dequantize(q, fmt) as f32, fmt), q);
        }
    }
}
