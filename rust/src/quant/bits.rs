//! Bit-position utilities over weight magnitudes.
//!
//! The kneading compiler and the bit-statistics analysis both reason
//! about "which bit positions of which weights are essential (1)".

use super::QWeight;

/// Is bit `b` of `w`'s magnitude set?
#[inline]
pub fn bit_is_set(w: QWeight, b: u32) -> bool {
    (w.unsigned_abs() >> b) & 1 == 1
}

/// Number of essential bits (1s) in the magnitude, restricted to the
/// low `bits` positions.
#[inline]
pub fn essential_bits(w: QWeight, bits: u32) -> u32 {
    let mask = if bits >= 32 { u32::MAX } else { (1u32 << bits) - 1 };
    (w.unsigned_abs() & mask).count_ones()
}

/// Per-bit-position popcount across a slice of weights: `out[b]` = how
/// many weights have an essential bit at position `b`. This is the
/// quantity that bounds kneaded-lane length (§III.B): a group kneads to
/// `max_b out[b]` kneaded weights.
pub fn popcount_per_position(weights: &[QWeight], bits: u32) -> Vec<u32> {
    let mut out = vec![0u32; bits as usize];
    for &w in weights {
        let mut mag = w.unsigned_abs();
        // Only low `bits` positions participate.
        if bits < 32 {
            mag &= (1u32 << bits) - 1;
        }
        while mag != 0 {
            let b = mag.trailing_zeros();
            out[b as usize] += 1;
            mag &= mag - 1;
        }
    }
    out
}

/// Iterator over the set bit positions of a weight's magnitude,
/// ascending. Allocation-free — used in the kneader's hot loop.
#[derive(Debug, Clone)]
pub struct BitIter {
    mag: u32,
}

impl BitIter {
    pub fn new(w: QWeight, bits: u32) -> Self {
        let mut mag = w.unsigned_abs();
        if bits < 32 {
            mag &= (1u32 << bits) - 1;
        }
        Self { mag }
    }
}

impl Iterator for BitIter {
    type Item = u32;

    #[inline]
    fn next(&mut self) -> Option<u32> {
        if self.mag == 0 {
            return None;
        }
        let b = self.mag.trailing_zeros();
        self.mag &= self.mag - 1;
        Some(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn bit_is_set_uses_magnitude() {
        assert!(bit_is_set(0b101, 0));
        assert!(!bit_is_set(0b101, 1));
        assert!(bit_is_set(-0b101, 2)); // negative: magnitude bits
    }

    #[test]
    fn essential_bits_counts_and_masks() {
        assert_eq!(essential_bits(0b1011, 16), 3);
        assert_eq!(essential_bits(-0b1011, 16), 3);
        assert_eq!(essential_bits(0b1_0000_0001, 8), 1); // bit 8 masked off
        assert_eq!(essential_bits(0, 16), 0);
    }

    #[test]
    fn popcount_matches_manual() {
        let ws = [0b0011, 0b0101, -0b0001, 0b1000];
        let pc = popcount_per_position(&ws, 4);
        assert_eq!(pc, vec![3, 1, 1, 1]);
    }

    #[test]
    fn bit_iter_matches_essential_count() {
        prop::run(
            "BitIter yields exactly the set bits",
            |r: &mut Rng| prop::gen::weight(r, 16),
            |&w| {
                let via_iter: Vec<u32> = BitIter::new(w, 16).collect();
                if via_iter.len() != essential_bits(w, 16) as usize {
                    return Err("count mismatch".into());
                }
                for &b in &via_iter {
                    if !bit_is_set(w, b) {
                        return Err(format!("bit {b} reported but not set"));
                    }
                }
                if via_iter.windows(2).any(|p| p[0] >= p[1]) {
                    return Err("not ascending".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn popcount_max_bounds_kneaded_length() {
        // The kneading invariant this quantity feeds (sanity anchor).
        let ws = [0x7FFF, 0x0001, 0x0003];
        let pc = popcount_per_position(&ws, 16);
        assert_eq!(*pc.iter().max().unwrap(), 3); // bit 0 set in all three
    }
}
