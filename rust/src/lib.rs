//! # Tetris — re-architected CNN computation for ML accelerators
//!
//! Full-system reproduction of *Tetris: Re-architecting Convolutional
//! Neural Network Computation for Machine Learning Accelerators*
//! (Lu et al., 2018): the weight-kneading compiler, the SAC
//! (split-and-accumulate) computing pattern, a cycle-level model of the
//! Tetris accelerator plus the DaDianNao and PRA (bit-pragmatic)
//! baselines, the energy/area model behind the paper's evaluation, and a
//! serving coordinator that drives batched inference through either the
//! timing simulators or an AOT-compiled XLA golden model.
//!
//! ## Layer map
//!
//! * [`quant`] — fixed-point formats (fp16 Q-format / int8) and bit tools.
//! * [`model`] — network zoo (AlexNet, GoogleNet, VGG-16/19, NiN),
//!   tensors, synthetic + trained weight sources.
//! * [`kneading`] — the paper's §III.B weight-kneading compiler.
//! * [`sac`] — the paper's §III.C SAC functional units (bit-exact).
//! * [`plan`] — compile-once execution plans: a [`plan::CompiledNetwork`]
//!   kneads every layer's filter lanes exactly once and records a
//!   generic op graph derived from `model::zoo` topology; its executor
//!   parallelizes the conv hot loop (see DESIGN.md §Compile/execute).
//! * [`sim`] — cycle-level simulators: Tetris, DaDianNao, PRA.
//! * [`energy`] — 65nm component energy/area tables, power + EDP model.
//! * [`latency`] — gate-delay model behind the paper's Figure 1.
//! * [`analysis`] — bit-level statistics (Table 1, Figure 2).
//! * [`engine`] — **the serving façade**: typed [`engine::EngineBuilder`]
//!   options, a multi-model registry compiled once per model, and one
//!   [`engine::InferSession`] submit/poll surface over both backends
//!   (kneaded-SAC and PJRT). Start here for serving.
//! * [`cluster`] — scale-out on top of the engine: wire protocol,
//!   TCP shard servers, a consistent-hash router, a crash-restarting
//!   supervisor, and a fault-tolerant load generator.
//! * [`coordinator`] — serving substrate the engine drives (request
//!   types, dynamic batcher, metrics, backends; the legacy `Server`
//!   shim).
//! * [`runtime`] — PJRT/XLA runtime that loads `artifacts/*.hlo.txt`
//!   (behind the `xla` feature) plus the quantized SAC pipeline.
//! * [`report`] — regenerates every table and figure of the paper.
//! * [`util`] — in-repo substrates (RNG, JSON, CLI, bench harness,
//!   thread pool, property testing) — this environment is offline, so
//!   these are built from scratch rather than pulled from crates.io.

pub mod analysis;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod engine;
pub mod kneading;
pub mod latency;
pub mod model;
pub mod plan;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sac;
pub mod sim;
pub mod util;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Crate-wide error type (hand-rolled — `thiserror` is unavailable
/// offline; the `Display` strings match the previous derive output).
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    Xla(String),
    Config(String),
    Artifact(String),
    Coordinator(String),
    Shape(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Io(e) => write!(f, "I/O error: {e}"),
            Error::Xla(msg) => write!(f, "XLA error: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Coordinator(msg) => write!(f, "coordinator error: {msg}"),
            Error::Shape(msg) => write!(f, "shape error: {msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(all(feature = "xla", feature = "xla-vendored"))]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<crate::util::json::ParseError> for Error {
    fn from(e: crate::util::json::ParseError) -> Self {
        Error::Config(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_matches_previous_derive() {
        assert_eq!(Error::Config("x".into()).to_string(), "config error: x");
        assert_eq!(Error::Shape("bad".into()).to_string(), "shape error: bad");
        let io = Error::from(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(io.to_string().starts_with("I/O error: "));
        use std::error::Error as _;
        assert!(io.source().is_some());
        assert!(Error::Xla("x".into()).source().is_none());
    }
}
