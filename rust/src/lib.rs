//! # Tetris — re-architected CNN computation for ML accelerators
//!
//! Full-system reproduction of *Tetris: Re-architecting Convolutional
//! Neural Network Computation for Machine Learning Accelerators*
//! (Lu et al., 2018): the weight-kneading compiler, the SAC
//! (split-and-accumulate) computing pattern, a cycle-level model of the
//! Tetris accelerator plus the DaDianNao and PRA (bit-pragmatic)
//! baselines, the energy/area model behind the paper's evaluation, and a
//! serving coordinator that drives batched inference through either the
//! timing simulators or an AOT-compiled XLA golden model.
//!
//! ## Layer map
//!
//! * [`quant`] — fixed-point formats (fp16 Q-format / int8) and bit tools.
//! * [`model`] — network zoo (AlexNet, GoogleNet, VGG-16/19, NiN),
//!   tensors, synthetic + trained weight sources.
//! * [`kneading`] — the paper's §III.B weight-kneading compiler.
//! * [`sac`] — the paper's §III.C SAC functional units (bit-exact).
//! * [`sim`] — cycle-level simulators: Tetris, DaDianNao, PRA.
//! * [`energy`] — 65nm component energy/area tables, power + EDP model.
//! * [`latency`] — gate-delay model behind the paper's Figure 1.
//! * [`analysis`] — bit-level statistics (Table 1, Figure 2).
//! * [`coordinator`] — serving engine (router, batcher, workers).
//! * [`runtime`] — PJRT/XLA runtime that loads `artifacts/*.hlo.txt`.
//! * [`report`] — regenerates every table and figure of the paper.
//! * [`util`] — in-repo substrates (RNG, JSON, CLI, bench harness,
//!   thread pool, property testing) — this environment is offline, so
//!   these are built from scratch rather than pulled from crates.io.

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod kneading;
pub mod latency;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sac;
pub mod sim;
pub mod util;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("I/O error: {0}")]
    Io(#[from] std::io::Error),
    #[error("XLA error: {0}")]
    Xla(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("artifact error: {0}")]
    Artifact(String),
    #[error("coordinator error: {0}")]
    Coordinator(String),
    #[error("shape error: {0}")]
    Shape(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<crate::util::json::ParseError> for Error {
    fn from(e: crate::util::json::ParseError) -> Self {
        Error::Config(e.to_string())
    }
}
