//! Tetris CLI — leader entrypoint.
//!
//! ```text
//! tetris report <table1|fig1|fig2|fig8|fig9|fig10|fig11|table2|all> [--csv-dir D]
//! tetris simulate --network vgg16 --accel tetris --mode fp16 --ks 16 [--activations] [--schedule]
//! tetris tune     --network vgg16 --budget-mb 1 --workers 2 [--measure]
//! tetris knead    --network alexnet --ks 16 --mode fp16
//! tetris serve    --requests 64 --max-batch 8 --workers 2 --network vgg16
//! tetris shard    --listen 127.0.0.1:0 --models tiny,nin:16:64
//! tetris cluster  --shards 2 --models tiny --requests 64 [--kill-one]
//! tetris golden   --dir artifacts
//! ```

use tetris::config::{AccelConfig, Mode};
use tetris::model::zoo;
use tetris::util::cli::Args;

const USAGE: &str = "\
tetris — Tetris accelerator reproduction (weight kneading + SAC)

Subcommands:
  report <which>   regenerate a paper table/figure (table1, fig1, fig2,
                   fig8, fig9, fig10, fig11, table2, all)
  simulate         run one network through one accelerator timing model
  tune             run the schedule auto-tuner: scored walk × tile
                   candidates and the chosen schedule for a budget
  knead            print kneading statistics for a network
  serve            start the serving engine with a synthetic load
                   (multi-model: tiny CNN + a scaled --network copy)
  shard            serve one engine over TCP (cluster wire protocol)
  cluster          spawn N supervised shards, route closed-loop load
                   through the consistent-hash router, print reports
  golden           execute the AOT golden model from artifacts/ via PJRT

Run `tetris <subcommand> --help` for options.
";

fn main() {
    if let Err(msg) = run() {
        eprintln!("{msg}");
        std::process::exit(1);
    }
}

fn run() -> Result<(), String> {
    let argv: Vec<String> = std::env::args().collect();
    let sub = argv.get(1).map(String::as_str);
    match sub {
        Some("report") => {
            let args = Args::new("tetris report — regenerate paper tables/figures")
                .opt("csv-dir", "", "directory for CSV output (empty = none)")
                .opt("seed", "0x7e7215", "random seed for synthetic weights")
                .parse_env(2)?;
            let which = args
                .positional()
                .first()
                .cloned()
                .ok_or_else(|| format!("report: missing <which>\n\n{USAGE}"))?;
            let csv_dir = match args.get("csv-dir") {
                "" => None,
                d => Some(std::path::PathBuf::from(d)),
            };
            let seed = args.get_u64("seed")?;
            tetris::report::run(&which, seed, csv_dir.as_deref()).map_err(|e| e.to_string())
        }
        Some("simulate") => {
            let args = Args::new("tetris simulate — one network, one accelerator")
                .opt("network", "vgg16", "alexnet|googlenet|vgg16|vgg19|nin")
                .opt("accel", "tetris", "tetris|dadn|pra")
                .opt("mode", "fp16", "fp16|int8")
                .opt("ks", "16", "kneading stride")
                .opt("seed", "0x7e7215", "random seed")
                .flag("include-fc", "also simulate the declared FC heads (VGG fc6-8, GoogleNet loss3)")
                .flag("activations", "measure the post-ReLU activation profile on a traced scaled copy and report dense vs tetris vs tetris+skip cycles")
                .flag("schedule", "also print the auto-tuner's schedule line (walk, tile, predicted peak) for this network under the process budget")
                .parse_env(2)?;
            let net = zoo::by_name(args.get("network")).map_err(|e| e.to_string())?;
            let mode: Mode = args.get("mode").parse()?;
            let cfg = AccelConfig { ks: args.get_usize("ks")?, mode, ..AccelConfig::default() };
            cfg.validate()?;
            let seed = args.get_u64("seed")?;
            let rep = tetris::report::simulate_one(
                &net,
                args.get("accel"),
                &cfg,
                seed,
                args.get_bool("include-fc"),
                args.get_bool("activations"),
            )
            .map_err(|e| e.to_string())?;
            println!("{rep}");
            if args.get_bool("schedule") {
                let line = tetris::report::schedule_line(&net, &cfg, seed)
                    .map_err(|e| e.to_string())?;
                println!("{line}");
            }
            Ok(())
        }
        Some("tune") => {
            let args = Args::new("tetris tune — schedule auto-tuner report")
                .opt("network", "vgg16", "alexnet|googlenet|vgg16|vgg19|nin")
                .opt("budget-mb", "256", "per-worker feature-map memory budget in MiB")
                .opt("workers", "0", "worker fan-out to tune for (0 = host default)")
                .opt("scale", "1", "channel divisor for a scaled-down copy (1 = full size)")
                .opt("hw", "0", "input spatial size override (0 = declared size)")
                .opt("ks", "16", "kneading stride")
                .opt("mode", "fp16", "fp16|int8")
                .opt("seed", "0x7e7215", "random seed for synthetic weights")
                .flag("measure", "execute one traced image with the chosen schedule and print measured vs predicted peak")
                .parse_env(2)?;
            let mut net = zoo::by_name(args.get("network")).map_err(|e| e.to_string())?;
            let scale = args.get_usize("scale")?.max(1);
            let hw = args.get_usize("hw")?;
            if scale != 1 || hw != 0 {
                let hw = if hw == 0 { net.layers[0].in_hw } else { hw };
                net = net.scaled(scale, hw);
            }
            let mode: Mode = args.get("mode").parse()?;
            let cfg = AccelConfig { ks: args.get_usize("ks")?, mode, ..AccelConfig::default() };
            cfg.validate()?;
            let workers = match args.get_usize("workers")? {
                0 => tetris::util::pool::worker_count(),
                n => n,
            };
            let rep = tetris::report::tune_report(
                &net,
                &cfg,
                args.get_u64("budget-mb")? * 1024 * 1024,
                workers,
                args.get_u64("seed")?,
                args.get_bool("measure"),
            )
            .map_err(|e| e.to_string())?;
            print!("{rep}");
            Ok(())
        }
        Some("knead") => {
            let args = Args::new("tetris knead — kneading statistics")
                .opt("network", "alexnet", "network name")
                .opt("ks", "16", "kneading stride")
                .opt("mode", "fp16", "fp16|int8")
                .opt("seed", "0x7e7215", "random seed")
                .parse_env(2)?;
            let net = zoo::by_name(args.get("network")).map_err(|e| e.to_string())?;
            let mode: Mode = args.get("mode").parse()?;
            tetris::report::knead_stats(&net, args.get_usize("ks")?, mode, args.get_u64("seed")?)
                .map_err(|e| e.to_string())
        }
        Some("serve") => {
            let args = Args::new("tetris serve — engine with synthetic multi-model load")
                .opt("requests", "64", "number of requests to issue")
                .opt("max-batch", "8", "dynamic batcher upper bound")
                .opt("workers", "2", "worker threads in the engine pool")
                .opt("network", "vgg16", "second registered model (scaled copy); tiny CNN always serves")
                .opt("seed", "0x7e7215", "random seed")
                .parse_env(2)?;
            let net = zoo::by_name(args.get("network")).map_err(|e| e.to_string())?;
            tetris::coordinator::demo::run_synthetic_load(
                &net,
                args.get_usize("requests")?,
                args.get_usize("max-batch")?,
                args.get_usize("workers")?,
                args.get_u64("seed")?,
            )
            .map_err(|e| e.to_string())
        }
        Some("shard") => {
            let args = Args::new("tetris shard — one engine behind a TCP listener")
                .opt("listen", "", "bind address (empty = TETRIS_LISTEN, else 127.0.0.1:0)")
                .opt("name", "shard", "shard name advertised in the Hello frame")
                .opt("models", "tiny", "comma list of name[:scale[:hw]] entries, e.g. tiny,nin:16:64")
                .opt("workers", "2", "worker threads in the shard's engine pool")
                .opt("seed", "0x7e7215", "synthetic-weight seed (same seed on every shard = bit-identical models)")
                .opt("max-batch", "8", "dynamic batcher upper bound")
                .flag("supervised", "exit when stdin closes (set by the cluster supervisor)")
                .parse_env(2)?;
            let listen = match args.get("listen") {
                "" => tetris::engine::env::listen()
                    .unwrap_or_else(|| "127.0.0.1:0".parse().expect("static addr")),
                s => s.parse().map_err(|e| format!("shard: bad --listen `{s}`: {e}"))?,
            };
            tetris::cluster::shard_main(tetris::cluster::ShardCliOpts {
                name: args.get("name").to_string(),
                listen,
                models: args.get("models").to_string(),
                workers: args.get_usize("workers")?.max(1),
                seed: args.get_u64("seed")?,
                max_batch: args.get_usize("max-batch")?.max(1),
                supervised: args.get_bool("supervised"),
            })
            .map_err(|e| e.to_string())
        }
        Some("cluster") => {
            let args = Args::new("tetris cluster — supervised shards + router + loadgen")
                .opt("shards", "0", "shard process count (0 = TETRIS_SHARDS, default 2)")
                .opt("models", "tiny", "comma list of name[:scale[:hw]] entries registered on every shard")
                .opt("requests", "64", "total closed-loop requests across all clients")
                .opt("clients", "4", "concurrent closed-loop client threads")
                .opt("workers", "2", "worker threads per shard engine")
                .opt("seed", "0x7e7215", "synthetic-weight + loadgen seed")
                .opt("max-batch", "8", "per-shard dynamic batcher upper bound")
                .opt("timeout-ms", "0", "router per-request deadline (0 = TETRIS_RPC_TIMEOUT_MS, default 5000)")
                .flag("kill-one", "kill shard-0 mid-flight and prove typed completion of every outstanding ticket")
                .parse_env(2)?;
            let shards = match args.get_usize("shards")? {
                0 => tetris::engine::env::shards(),
                n => n,
            };
            let timeout = match args.get_u64("timeout-ms")? {
                0 => tetris::engine::env::rpc_timeout(),
                ms => std::time::Duration::from_millis(ms),
            };
            tetris::cluster::cluster_main(tetris::cluster::ClusterCliOpts {
                shards,
                models: args.get("models").to_string(),
                requests: args.get_usize("requests")?,
                clients: args.get_usize("clients")?.max(1),
                workers: args.get_usize("workers")?.max(1),
                seed: args.get_u64("seed")?,
                max_batch: args.get_usize("max-batch")?.max(1),
                timeout,
                kill_one: args.get_bool("kill-one"),
                program: None,
            })
            .map_err(|e| e.to_string())
        }
        Some("golden") => {
            let args = Args::new("tetris golden — run AOT model via PJRT")
                .opt("dir", "artifacts", "artifacts directory")
                .parse_env(2)?;
            tetris::runtime::golden::run_from_dir(std::path::Path::new(args.get("dir")))
                .map_err(|e| e.to_string())
        }
        Some("--help") | Some("-h") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand `{other}`\n\n{USAGE}")),
    }
}
