#!/usr/bin/env python3
"""Bench-regression gate: diff a fresh hot-path bench JSON against the
committed baseline and fail on median or peak-memory regressions beyond
tolerance.

Usage:
    scripts/bench_compare.py BASELINE.json FRESH.json [--tolerance 0.30]
    scripts/bench_compare.py --promote FRESH.json [--out BENCH_baseline.json]

Promotion: `--promote` rewrites FRESH.json (a run from the CI runner
class itself — use the `bench-baseline` workflow_dispatch job and
download its artifact) as a gating baseline: provisional flipped to
false, tolerance and provenance header attached. Commit the `--out`
file and the bench-regression job starts failing on regressions.

Both files are `util::bench::Harness` JSON reports
(`cargo bench --bench hotpath -- --json <path>`). The baseline may
additionally carry:

    "provisional": true   # bootstrap mode: report, never fail
    "tolerance": 0.30     # default timing tolerance (CLI flag overrides)
    "peak_tolerance": 0.10  # allowed fractional peak-bytes growth

Rules, per baseline entry with a positive median (metric-only rows have
median 0 and are skipped by the timing gate):

  * fresh median  >  baseline * (1 + tolerance)  ->  REGRESSION (fails)
  * entry missing from the fresh report          ->  MISSING    (fails)
  * fresh-only entries (timed or metric-only)    ->  listed as new, pass

Peak-memory gate, per metric key ending in `_peak_bytes` that both the
baseline and the fresh entry carry (memory is deterministic, so the
tolerance is tight — default 10%):

  * fresh peak  >  baseline peak * 1.10  ->  PEAK REGRESSION (fails)
  * peak metric present only in one side ->  listed, never fails

Activation-skipping gates (ISSUE 8), same both-sides rule but ZERO
tolerance — skip counters and simulated cycle counts are deterministic
(fixed seeds, integer arithmetic), so any movement in the bad direction
is a real regression:

  * metric key ending `_skipped_rows` or `_skipped_windows`:
      fresh < baseline  ->  SKIP REGRESSION (fails: the lane lost skips)
  * metric key ending `_sim_cycles`:
      fresh > baseline  ->  SIM REGRESSION (fails: simulated cycles rose)

Moving the *good* way (more skips, fewer cycles) passes and shows in
the log — re-promote the baseline to bank the improvement.

Sections and metrics that exist only in the fresh report NEVER fail the
gate: new benches land before their baseline is re-promoted, and the
gate must not punish adding coverage.

Exit codes: 0 ok / 1 regressions or missing entries / 2 usage or parse
errors. Timing gates are inherently noisy — the tolerance is the knob;
keep it generous (>=0.25) for shared CI runners. Peak-bytes, skip and
sim-cycle gates are NOT noisy, hence their separate tight/zero
tolerances.
"""

import argparse
import json
import sys

PEAK_SUFFIX = "_peak_bytes"
SKIP_SUFFIXES = ("_skipped_rows", "_skipped_windows")
SIM_SUFFIX = "_sim_cycles"
DEFAULT_PEAK_TOLERANCE = 0.10


def load_report(path):
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_compare: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    results = doc.get("results")
    if not isinstance(results, list):
        print(f"bench_compare: {path} has no 'results' array", file=sys.stderr)
        sys.exit(2)
    medians, metrics = {}, {}
    for entry in results:
        name = entry.get("name")
        median = entry.get("median_s")
        if isinstance(name, str) and isinstance(median, (int, float)):
            medians[name] = float(median)
        m = entry.get("metrics")
        if isinstance(name, str) and isinstance(m, dict):
            metrics[name] = {
                k: float(v) for k, v in m.items() if isinstance(v, (int, float))
            }
    return doc, medians, metrics


def promote(fresh_path, out_path, tolerance):
    doc, medians, _ = load_report(fresh_path)
    timed = sum(1 for m in medians.values() if m > 0.0)
    if timed == 0:
        print(f"bench_compare: {fresh_path} has no timed entries to promote", file=sys.stderr)
        sys.exit(2)
    doc.pop("provisional", None)
    doc.pop("note", None)
    file_tol = float(doc.pop("tolerance", 0.30))
    peak_tol = float(doc.pop("peak_tolerance", DEFAULT_PEAK_TOLERANCE))
    tol = tolerance if tolerance is not None else file_tol
    promoted = {
        "note": (
            "Bench-regression baseline for scripts/bench_compare.py, promoted "
            f"from {fresh_path} via --promote. Re-promote from the SAME runner "
            "class CI uses (the bench-baseline workflow_dispatch job) whenever "
            "hot paths change shape; a baseline timed on a different machine "
            "makes absolute-median comparison meaningless."
        ),
        "provisional": False,
        "tolerance": tol,
        "peak_tolerance": peak_tol,
    }
    promoted.update(doc)
    with open(out_path, "w", encoding="utf-8") as f:
        json.dump(promoted, f, indent=1)
        f.write("\n")
    print(
        f"bench_compare: promoted {fresh_path} -> {out_path} "
        f"({timed} timed entries, tolerance {promoted['tolerance']:.0%}, "
        f"peak tolerance {peak_tol:.0%}, gating ON)"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", nargs="?")
    ap.add_argument("fresh", nargs="?")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=None,
        help="allowed fractional slowdown (default: baseline's "
        "'tolerance' field, else 0.30)",
    )
    ap.add_argument(
        "--peak-tolerance",
        type=float,
        default=None,
        help="allowed fractional growth of *_peak_bytes metrics "
        "(default: baseline's 'peak_tolerance' field, else 0.10)",
    )
    ap.add_argument(
        "--promote",
        metavar="FRESH",
        help="rewrite FRESH as a gating (non-provisional) baseline and exit",
    )
    ap.add_argument(
        "--out",
        default="BENCH_baseline.json",
        help="output path for --promote (default: BENCH_baseline.json)",
    )
    args = ap.parse_args()

    if args.promote:
        promote(args.promote, args.out, args.tolerance)
        return
    if not args.baseline or not args.fresh:
        ap.error("BASELINE and FRESH are required unless --promote is given")

    base_doc, base, base_metrics = load_report(args.baseline)
    _, fresh, fresh_metrics = load_report(args.fresh)
    provisional = bool(base_doc.get("provisional", False))
    tolerance = args.tolerance
    if tolerance is None:
        tolerance = float(base_doc.get("tolerance", 0.30))
    peak_tolerance = args.peak_tolerance
    if peak_tolerance is None:
        peak_tolerance = float(base_doc.get("peak_tolerance", DEFAULT_PEAK_TOLERANCE))

    timed = {n: m for n, m in base.items() if m > 0.0}
    regressions, missing, ok = [], [], []
    for name, base_median in sorted(timed.items()):
        if name not in fresh:
            missing.append(name)
            continue
        fresh_median = fresh[name]
        ratio = fresh_median / base_median if base_median else float("inf")
        line = f"{name:<48} base {base_median * 1e3:9.3f} ms  fresh {fresh_median * 1e3:9.3f} ms  x{ratio:5.2f}"
        if fresh_median > base_median * (1.0 + tolerance):
            regressions.append(line)
        else:
            ok.append(line)

    # Deterministic metric gates, keyed by suffix, gating only when a
    # key is present on BOTH sides of an entry. One-sided metrics are
    # informational only — new sections/metrics never fail.
    #   *_peak_bytes      : at most baseline * (1 + peak_tolerance)
    #   *_skipped_rows /
    #   *_skipped_windows : at least baseline (exact-or-better)
    #   *_sim_cycles      : at most baseline (exact-or-better)
    peak_regressions, peak_ok, metric_new = [], [], []
    skip_regressions, skip_ok = [], []
    sim_regressions, sim_ok = [], []
    for name in sorted(set(base_metrics) | set(fresh_metrics)):
        b = base_metrics.get(name, {})
        f = fresh_metrics.get(name, {})
        for key in sorted(set(b) | set(f)):
            if key.endswith(PEAK_SUFFIX):
                gate = "peak"
            elif key.endswith(SKIP_SUFFIXES):
                gate = "skip"
            elif key.endswith(SIM_SUFFIX):
                gate = "sim"
            else:
                continue
            label = f"{name} :: {key}"
            if key in b and key in f:
                ratio = f[key] / b[key] if b[key] else float("inf")
                if gate == "peak":
                    line = (
                        f"{label:<60} base {b[key] / 1e6:10.3f} MB  "
                        f"fresh {f[key] / 1e6:10.3f} MB  x{ratio:5.2f}"
                    )
                    if b[key] > 0.0 and f[key] > b[key] * (1.0 + peak_tolerance):
                        peak_regressions.append(line)
                    else:
                        peak_ok.append(line)
                elif gate == "skip":
                    line = (
                        f"{label:<60} base {b[key]:14.0f}  "
                        f"fresh {f[key]:14.0f}  x{ratio:5.2f}"
                    )
                    # Skip counters are deterministic: any drop means
                    # the lane stopped eliding work it used to elide.
                    if f[key] < b[key]:
                        skip_regressions.append(line)
                    else:
                        skip_ok.append(line)
                else:
                    line = (
                        f"{label:<60} base {b[key]:14.0f}  "
                        f"fresh {f[key]:14.0f}  x{ratio:5.2f}"
                    )
                    # Simulated cycles are deterministic: any rise is a
                    # timing-model regression.
                    if f[key] > b[key]:
                        sim_regressions.append(line)
                    else:
                        sim_ok.append(line)
            elif key in f:
                metric_new.append(f"{label} (no baseline yet)")
            # Baseline-only gated metrics ride on the MISSING entry
            # check when the whole section vanished; a renamed metric
            # inside a surviving section is a baseline-refresh matter,
            # not a gate failure.

    # Fresh-only sections — timed or metric-only — are reported and
    # always pass: baselines trail new benches by one promotion.
    known = set(base) | set(base_metrics)
    new = sorted(
        n
        for n in set(fresh) | set(fresh_metrics)
        if n not in known and (fresh.get(n, 0.0) > 0.0 or fresh_metrics.get(n))
    )

    print(
        f"bench_compare: {len(timed)} baseline entries, tolerance {tolerance:.0%}, "
        f"peak tolerance {peak_tolerance:.0%}"
        + (" (provisional baseline: never fails)" if provisional else "")
    )
    for line in ok:
        print(f"  ok          {line}")
    for line in regressions:
        print(f"  REGRESSION  {line}")
    for line in peak_ok:
        print(f"  peak ok     {line}")
    for line in peak_regressions:
        print(f"  PEAK REGR   {line}")
    for line in skip_ok:
        print(f"  skip ok     {line}")
    for line in skip_regressions:
        print(f"  SKIP REGR   {line}")
    for line in sim_ok:
        print(f"  sim ok      {line}")
    for line in sim_regressions:
        print(f"  SIM REGR    {line}")
    for name in missing:
        print(f"  MISSING     {name} (in baseline, absent from fresh run)")
    for name in metric_new:
        print(f"  new         {name}")
    for name in new:
        print(f"  new         {name} (no baseline yet)")

    if not timed:
        print(
            "bench_compare: baseline has no timed entries yet — populate it "
            "from a trusted runner:\n  cd rust && cargo bench --bench hotpath "
            "-- --json ../BENCH_baseline.json\nthen set \"provisional\": false."
        )

    failures = regressions or peak_regressions or skip_regressions or sim_regressions or missing
    if failures and not provisional:
        print(
            f"bench_compare: FAIL — {len(regressions)} timing regression(s), "
            f"{len(peak_regressions)} peak-memory regression(s), "
            f"{len(skip_regressions)} skip-counter regression(s), "
            f"{len(sim_regressions)} simulated-cycle regression(s), "
            f"{len(missing)} missing hot path(s)",
            file=sys.stderr,
        )
        sys.exit(1)
    print("bench_compare: OK")


if __name__ == "__main__":
    main()
