#!/usr/bin/env bash
# Tier-1 verification: offline build + tests, plus clippy when present.
# Run from anywhere: `scripts/verify.sh [--quick]`
#
#   --quick   skip the release build (debug tests + clippy only) —
#             for doc-only or comment-only changes where the release
#             codegen pass adds nothing but wall time.
set -euo pipefail

QUICK=0
for arg in "$@"; do
    case "$arg" in
        --quick) QUICK=1 ;;
        *)
            echo "usage: scripts/verify.sh [--quick]" >&2
            exit 2
            ;;
    esac
done

cd "$(dirname "$0")/../rust"

if [ "$QUICK" -eq 1 ]; then
    echo "== release build skipped (--quick) =="
else
    echo "== cargo build --release =="
    cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

# The whole-network streaming sweep (ISSUE 6) at an explicit case
# count: `pipelined ≡ streaming ≡ tiled ≡ reference` across the zoo
# with zero halo recompute. The suite above already runs it at the
# default 12 cases; this leg widens the draw under the documented
# TETRIS_PROP_CASES knob so the budget/tile/worker space gets real
# coverage on every verify.
echo "== streaming sweep (TETRIS_PROP_CASES=24) =="
TETRIS_PROP_CASES=24 cargo test -q --test plan_streaming \
    pipelined_walk_joins_the_equivalence_class_zoo_wide

# The auto-tuner validation sweep (ISSUE 7) under the same knob: the
# cost model's predicted peaks must bracket execute_traced's measured
# peaks across zoo × walks × tiles × budgets, and the tuner must never
# pick an over-budget schedule when an in-budget candidate exists.
echo "== auto-tuner sweep (TETRIS_PROP_CASES=24) =="
TETRIS_PROP_CASES=24 cargo test -q --test plan_tune

# The activation-skipping sweep (ISSUE 8) under the same knob:
# skip-on ≡ skip-off ≡ reference across networks × walks × tiles ×
# budgets, with the trace counters proving the lane actually elided
# SAC work on every drawn case, plus the three-way simulated-cycle
# ordering (Tetris+skip < Tetris < DaDN) per zoo model.
echo "== activation-skipping sweep (TETRIS_PROP_CASES=24) =="
TETRIS_PROP_CASES=24 cargo test -q --test plan_skip

# The decoded-lane kernel sweep (ISSUE 10) under the same knob:
# decoded ≡ legacy ≡ reference across networks × walks × tiles ×
# budgets × skip on/off, with identical slot-decode / segment-add /
# skip counters between the two kernels on every drawn case.
echo "== decoded-kernel sweep (TETRIS_PROP_CASES=24) =="
TETRIS_PROP_CASES=24 cargo test -q --test plan_kernel

# The cluster wire-codec sweep (ISSUE 9) under the same knob: arbitrary
# messages round-trip bit-exactly, and truncating or corrupting a frame
# anywhere is always rejected.
echo "== cluster wire sweep (TETRIS_PROP_CASES=24) =="
TETRIS_PROP_CASES=24 cargo test -q --test cluster wire_codec

if [ "$QUICK" -eq 0 ]; then
    # Tune smoke on a small model: the full candidate table, the chosen
    # schedule, and measured-vs-predicted peak from one traced image.
    echo "== tetris tune smoke (nin ÷16 @64², 8 MiB) =="
    cargo run --release --quiet -- tune --network nin --scale 16 --hw 64 \
        --budget-mb 8 --workers 2 --measure

    # Cluster smoke (ISSUE 9): two supervised shard processes on
    # loopback, closed-loop load through the consistent-hash router,
    # and the kill-one drill — shard-0 dies mid-flight, every
    # outstanding ticket must complete as a typed failure (zero
    # hangs) while the survivor keeps serving. Exit status is the
    # gate: cluster_main fails unless the accounting closes.
    echo "== tetris cluster smoke (2 shards, kill-one drill) =="
    cargo run --release --quiet -- cluster --shards 2 --models tiny \
        --requests 48 --clients 4 --workers 1 --kill-one
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy (all targets, -D warnings) =="
    cargo clippy --all-targets -- -D warnings
else
    echo "== clippy unavailable — skipped =="
fi

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --all -- --check
else
    echo "== rustfmt unavailable — skipped =="
fi

# The public API (the `engine` façade above all) must stay documented:
# broken intra-doc links or missing docs on the redesigned surface fail
# the build rather than rotting silently.
echo "== cargo doc --no-deps (-D warnings) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "verify OK"
